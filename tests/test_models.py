"""Per-arch smoke tests (reduced configs): forward/train step, shapes, NaNs,
prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs import get_config, list_archs
from repro.models.api import get_model, synth_batch
from repro.train.train_step import TrainHParams, init_train_state, \
    make_train_step

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_and_grads(arch):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    batch = synth_batch(0, cfg, 2, 32)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    flat, _ = ravel_pytree(grads)
    assert bool(jnp.isfinite(flat).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    hp = TrainHParams(remat="none")
    step = jax.jit(make_train_step(cfg, hp))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = synth_batch(1, cfg, 2, 32)
    state, metrics = step(state, batch)
    l1 = float(metrics["loss"])
    state, metrics = step(state, batch)
    l2 = float(metrics["loss"])
    assert np.isfinite(l1) and np.isfinite(l2)
    assert l2 < l1 + 0.5   # training is not diverging on a repeated batch


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "qwen3-moe-30b-a3b",
                                  "rwkv6-3b", "jamba-v0.1-52b",
                                  "seamless-m4t-large-v2", "internvl2-2b"])
def test_prefill_decode_consistency(arch):
    """decode_step(prefill(prompt)) == forward(prompt + token)."""
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    batch = synth_batch(2, cfg, 2, 16)
    from repro.train.serve_step import make_decode_step, make_prefill_step
    pf = make_prefill_step(cfg, max_len=24)
    dec = make_decode_step(cfg)
    logits, state = pf(params, {k: v for k, v in batch.items()
                                if k != "labels"})
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None]
    nxt2, state, logits2 = dec(params, state, nxt, jax.random.PRNGKey(1))
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert nxt2.shape == (2, 1)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "seamless-m4t-large-v2",
                                  "rwkv6-3b"])
def test_prefill_unpacking_contract(arch):
    """make_prefill_step returns EXACTLY (logits, state) for every family.

    Regression for the serve-path bug where callers probed tuple arity
    (``out[1] if len(out) == 2 else (out[1], out[2])``): encdec's native
    prefill returns a 3-tuple, so the probe silently built a mis-shaped
    decode state.  The contract is now normalised inside make_prefill_step;
    the state must round-trip into decode_step unchanged in structure."""
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = synth_batch(2, cfg, 2, 16)
    from repro.train.serve_step import make_decode_step, make_prefill_step
    out = make_prefill_step(cfg, max_len=24)(
        params, {k: v for k, v in batch.items() if k != "labels"})
    assert isinstance(out, tuple) and len(out) == 2
    logits, state = out
    if cfg.family == "encdec":
        # encdec state is the (cache, cross) pair decode_step unpacks.
        assert isinstance(state, tuple) and len(state) == 2
    nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None]
    _, state2, _ = make_decode_step(cfg)(params, state, nxt,
                                         jax.random.PRNGKey(1))
    assert jax.tree.structure(state2) == jax.tree.structure(state)


def test_remat_matches_no_remat():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = synth_batch(3, cfg, 2, 32)
    l0 = float(model.loss_fn(params, batch, cfg, remat="none"))
    l1 = float(model.loss_fn(params, batch, cfg, remat="full"))
    assert abs(l0 - l1) < 1e-4


def test_grad_accum_matches_full_batch():
    from repro.train import optimizer as opt
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    batch = synth_batch(4, cfg, 4, 32)
    s0 = init_train_state(jax.random.PRNGKey(0), cfg)
    step1 = make_train_step(cfg, TrainHParams(remat="none", grad_accum=1))
    step2 = make_train_step(cfg, TrainHParams(remat="none", grad_accum=2))
    _, m1 = step1(jax.tree.map(jnp.copy, s0), batch)
    _, m2 = step2(jax.tree.map(jnp.copy, s0), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) < 2e-3
