"""Compressed sharded collectives + double-buffered intervals (DESIGN.md §11).

Three layers of guarantees:

* the ``pmin_compressed`` delta exchange is BIT-identical to ``lax.pmin``
  on 1/2/4/8 shards, including the adversarial corners (all-equal keys, a
  fully-converged zero-delta round, and cap overflow → the ``lax.cond``
  fallback to the dense reduction);
* every engine path (boruvka, filter_boruvka, batched) elects the exact
  same forest under ``collective="compressed"`` as under ``"pmin"``;
* ``interval_pipeline=1`` (double-buffered dispatch) produces
  byte-identical forests to the sequential loop and keeps the
  ``host_syncs == intervals + 1`` consumed-readback contract.

Shard sweeps run in subprocesses (device count is locked at jax init);
the wire-format / byte-model / knob-validation units run in-process so
the coverage gate sees :mod:`repro.sharding.collectives`.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


# ---------------------------------------------------------------------------
# direct collective: pmin_compressed ≡ lax.pmin on 1/2/4/8 shards
# ---------------------------------------------------------------------------

def test_pmin_compressed_bit_identity_1_2_4_8_shards():
    """Random deltas, all-equal keys, zero-delta round, and cap overflow
    all reduce bit-identically to ``lax.pmin`` on every shard count, for
    both engine value dtypes (uint64 best keys, uint32 hook parents)."""
    out = run_child("""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.experimental import enable_x64
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.sharding import collectives

N = 96
rows = []
for shards in (1, 2, 4, 8):
    mesh = make_mesh((shards,), ("x",))
    for dtype, inf in ((jnp.uint32, 2**32 - 1), (jnp.uint64, 2**64 - 1)):
        with enable_x64():
            default = jnp.full((N,), inf, dtype)
            rng = np.random.default_rng(shards)
            def case(n_improved, equal=False):
                data = np.full((shards, N), inf, np.uint64)
                if n_improved:
                    idx = rng.choice(N, size=n_improved, replace=False)
                    vals = rng.integers(1, 1 << 30, size=n_improved,
                                        dtype=np.uint64)
                    for s in range(shards):
                        if equal:
                            data[s, idx] = vals
                        else:
                            take = rng.random(n_improved) < 0.7
                            data[s, idx[take]] = vals[take] + s
                return jnp.asarray(data).astype(dtype)

            def both(x, cap):
                def f(xs):
                    x1 = xs[0]
                    a = jax.lax.pmin(x1, "x")
                    b = collectives.pmin_compressed(
                        x1, "x", default=default, cap=cap,
                        num_shards=shards)
                    return a[None], b[None]
                a, b = shard_map(f, mesh, in_specs=(P("x"),),
                                 out_specs=(P("x"), P("x")))(x)
                return (np.asarray(jax.device_get(a)),
                        np.asarray(jax.device_get(b)))

            for name, x, cap in [
                ("random", case(16), 32),
                ("all_equal", case(16, equal=True), 32),
                ("zero_delta", case(0), 32),
                ("overflow_fallback", case(64), 8),
            ]:
                a, b = both(x, cap)
                rows.append(dict(shards=shards, dtype=str(dtype.__name__),
                                 case=name,
                                 ok=bool(np.array_equal(a, b))))
print(json.dumps(rows))
""")
    rows = json.loads(out.strip().splitlines()[-1])
    assert len(rows) == 4 * 2 * 4
    bad = [r for r in rows if not r["ok"]]
    assert not bad, bad


# ---------------------------------------------------------------------------
# engines: compressed ≡ pmin forests on 1/2/4/8 shards
# ---------------------------------------------------------------------------

def test_engines_compressed_vs_pmin_1_2_4_8_shards():
    """boruvka and filter_boruvka elect the exact Kruskal forest under
    both collectives on every shard count; the compressed multi-shard
    runs actually engage the delta exchange at least once (comm_history
    witnesses a "compressed" interval) and honor the sync contract."""
    out = run_child("""
import numpy as np, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams

g = generators.generate("rmat", 9, seed=3)
want = kruskal_ref.kruskal(g).edge_mask
rows = []
for shards in (1, 2, 4, 8):
    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    for method in ("boruvka", "filter_boruvka"):
        masks = {}
        for coll in ("pmin", "compressed"):
            res, st = minimum_spanning_forest(
                g, method=method,
                params=GHSParams(collective=coll, check_frequency=2),
                mesh=mesh)
            masks[coll] = np.asarray(res.edge_mask)
            # filter merges several sub-solve ledgers (one trailing sync
            # each), so its merged contract is the inequality form
            sync_ok = (st.host_syncs == st.intervals + 1
                       if method == "boruvka"
                       else st.host_syncs > st.intervals >= 1)
            row = dict(shards=shards, method=method, collective=coll,
                       ok=bool(np.array_equal(masks[coll], want)),
                       sync_ok=bool(sync_ok))
            if method == "boruvka":
                modes = [m for (m, c, r, b) in st.comm_history]
                row["engaged"] = "compressed" in modes
                row["bytes"] = st.comm_bytes
            rows.append(row)
        rows.append(dict(shards=shards, method=method, collective="both",
                         ok=bool(np.array_equal(masks["pmin"],
                                                masks["compressed"])),
                         sync_ok=True))
print(json.dumps(rows))
""")
    rows = json.loads(out.strip().splitlines()[-1])
    assert len(rows) == 4 * 2 * 3
    bad = [r for r in rows if not (r["ok"] and r["sync_ok"])]
    assert not bad, bad
    # the delta exchange must actually carry the reduction somewhere on
    # multi-shard boruvka runs (not just fall back / stay dense)
    engaged = [r for r in rows
               if r.get("collective") == "compressed" and r["shards"] > 1
               and r["method"] == "boruvka"]
    assert any(r["engaged"] for r in engaged), engaged
    for r in engaged:
        assert r["bytes"] > 0


def test_batched_compressed_knob_and_pipeline():
    """The batched serving path accepts the knobs and stays bit-identical
    to per-graph solves under every (collective, interval_pipeline)
    combination — it never shards, so the knobs must be inert."""
    sys.path.insert(0, SRC)
    from repro.core import generators, kruskal_ref
    from repro.core.mst_api import minimum_spanning_forests
    from repro.core.params import GHSParams

    graphs = [generators.generate("rmat", 6, seed=s) for s in (1, 2, 3)]
    want = [kruskal_ref.kruskal(g).edge_mask for g in graphs]
    for coll in ("pmin", "compressed"):
        for pipe in (0, 1):
            forests, st = minimum_spanning_forests(
                graphs, params=GHSParams(collective=coll,
                                         interval_pipeline=pipe))
            for f, w in zip(forests, want):
                assert np.array_equal(np.asarray(f.edge_mask), w), (coll,
                                                                    pipe)
            # one trailing sync per bucketed interval_loop (merge sums them)
            assert st.host_syncs > st.intervals >= 1


# ---------------------------------------------------------------------------
# double-buffered intervals: pipeline 0 ≡ pipeline 1
# ---------------------------------------------------------------------------

def test_double_buffering_byte_identical_forests():
    """interval_pipeline=1 overlaps dispatch k+1 with readback k; the
    forests must stay byte-identical to the sequential loop for all three
    engines, the consumed-readback ledger must satisfy
    ``host_syncs == intervals + 1`` at both depths, and the overlapped
    run must actually overlap (overlapped_syncs == intervals, one
    speculative trailing dispatch)."""
    out = run_child("""
import numpy as np, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams

rows = []
mesh = make_mesh((4,), ("x",))
for method, scale in (("boruvka", 9), ("filter_boruvka", 9), ("ghs", 7)):
    g = generators.generate("rmat", scale, seed=5)
    want = kruskal_ref.kruskal(g).edge_mask
    masks = {}
    stats = {}
    for pipe in (0, 1):
        res, st = minimum_spanning_forest(
            g, method=method,
            params=GHSParams(interval_pipeline=pipe, collective="compressed",
                             check_frequency=2),
            mesh=mesh)
        masks[pipe] = np.asarray(res.edge_mask)
        stats[pipe] = st
    st0, st1 = stats[0], stats[1]
    def sync_ok(st):
        # filter merges sub-solve ledgers: inequality form (see above)
        if method == "filter_boruvka":
            return st.host_syncs > st.intervals >= 1
        return st.host_syncs == st.intervals + 1
    rows.append(dict(
        method=method,
        oracle=bool(np.array_equal(masks[1], want)),
        identical=bool(np.array_equal(masks[0], masks[1])),
        sync0=bool(sync_ok(st0)),
        sync1=bool(sync_ok(st1)),
        seq_no_overlap=bool(st0.overlapped_syncs == 0
                            and st0.speculative_intervals == 0),
        overlapped=bool(st1.overlapped_syncs == st1.intervals),
        speculative=st1.speculative_intervals))
print(json.dumps(rows))
""", devices=4)
    rows = json.loads(out.strip().splitlines()[-1])
    assert len(rows) == 3
    for r in rows:
        assert r["oracle"] and r["identical"], r
        assert r["sync0"] and r["sync1"], r
        assert r["seq_no_overlap"], r
        assert r["overlapped"], r
        assert r["speculative"] >= 1, r


# ---------------------------------------------------------------------------
# in-process units: wire format, byte model, knob validation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def _src_path():
    sys.path.insert(0, SRC)
    yield


def test_byte_models(_src_path):
    from repro.sharding import collectives

    # (P-1) ring steps, cap entries of (4-byte index + value lane) each
    assert collectives.compressed_bytes(cap=64, num_shards=4,
                                        value_bytes=8) == 3 * 64 * 12
    # dense all-reduce lower bound: 2 (P-1)/P · n · value lanes
    assert collectives.dense_bytes(4096, 8, 8) == 2 * 7 * 4096
    # a P=1 "exchange" is free on both models
    assert collectives.compressed_bytes(cap=64, num_shards=1,
                                        value_bytes=8) == 0
    assert collectives.dense_bytes(4096, 1, 8) == 0


def test_knob_validation(_src_path):
    from repro.core import runtime
    from repro.sharding import collectives

    assert runtime.resolve_collective("pmin") == "pmin"
    assert runtime.resolve_collective("compressed") == "compressed"
    with pytest.raises(ValueError, match="collective"):
        runtime.resolve_collective("gossip")
    assert collectives.resolve_collective("pmin") == "pmin"
    with pytest.raises(ValueError):
        collectives.resolve_collective("nope")
    assert runtime.resolve_interval_pipeline(0) == 0
    assert runtime.resolve_interval_pipeline(1) == 1
    with pytest.raises(ValueError, match="interval_pipeline"):
        runtime.resolve_interval_pipeline(2)


def test_pmin_compressed_single_shard_paths(_src_path):
    """Both the ring path and the overflow fallback lower and run on the
    real (single-device) test backend — shard-count-1 exchange is the
    identity, and a tiny cap routes through the dense fallback."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.sharding import collectives

    n = 32
    mesh = make_mesh((1,), ("x",))
    default = jnp.full((n,), jnp.uint32(0xFFFFFFFF), jnp.uint32)
    x = np.full((1, n), 0xFFFFFFFF, np.uint32)
    x[0, 3] = 7
    x[0, 21] = 9

    def run(cap):
        def f(xs):
            return collectives.pmin_compressed(
                xs[0], "x", default=default, cap=cap, num_shards=1)[None]
        return np.asarray(jax.device_get(
            shard_map(f, mesh, in_specs=(P("x"),),
                      out_specs=P("x"))(jnp.asarray(x))))[0]

    for cap in (8, 1):           # ring path; cap overflow → dense fallback
        got = run(cap)
        assert np.array_equal(got, x[0]), cap


def test_latency_hiding_flags(_src_path):
    from repro.sharding import collectives

    tpu = collectives.latency_hiding_flags("tpu")
    gpu = collectives.latency_hiding_flags("gpu")
    assert "latency_hiding_scheduler" in tpu
    assert "latency_hiding_scheduler" in gpu
    assert "while_loop_double_buffering" in gpu
    assert collectives.latency_hiding_flags("cpu") == ""
    with pytest.raises(ValueError):
        collectives.latency_hiding_flags("dsp")
    # the platform façade re-exports the same flag source
    from repro import platform as platform_lib
    assert platform_lib.latency_hiding_flags("gpu") == gpu
