"""Multi-device paths (shard_map engines, EP MoE, distributed train step).

Device count is locked at jax init, so these run in subprocesses with
forced host devices."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_boruvka_multidevice_exact():
    out = run_child("""
import numpy as np, jax, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.boruvka_dist import minimum_spanning_forest
mesh = make_mesh((8,), ("x",))
g = generators.generate("rmat", 10, seed=3)
want = kruskal_ref.kruskal(g)
got, stats = minimum_spanning_forest(g, mesh=mesh)
assert np.array_equal(got.edge_mask, want.edge_mask)
print(json.dumps(dict(ok=True, rounds=stats.rounds)))
""")
    assert json.loads(out.strip().splitlines()[-1])["ok"]


def test_ghs_multidevice_exact():
    out = run_child("""
import numpy as np, jax, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.ghs_message import minimum_spanning_forest
mesh = make_mesh((4,), ("x",))
g = generators.generate("rmat", 7, seed=5)
want = kruskal_ref.kruskal(g)
got, stats = minimum_spanning_forest(g, mesh=mesh)
assert np.array_equal(got.edge_mask, want.edge_mask)
print(json.dumps(dict(ok=True, steps=stats.supersteps,
                      remote=stats.sent_remote)))
""", devices=4)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["ok"] and rec["remote"] > 0   # real cross-shard traffic


def test_ep_moe_matches_ragged_when_dropfree():
    run_child("""
import jax, jax.numpy as jnp
from repro.models import moe, moe_ep
from repro.launch.mesh import make_host_mesh, make_rules
from repro.sharding.specs import use_sharding
from repro.models.config import ModelConfig
moe_ep.capacity = lambda tokens, cfg, e_pad: tokens   # drop-free
cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128, n_experts=16, top_k=2,
                  d_expert=32, n_shared=1, d_shared=64,
                  compute_dtype="float32")
p = moe.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 64))
y_ref, _ = moe.moe_apply(p, x, cfg)
mesh = make_host_mesh(2, 4)
with use_sharding(mesh, make_rules(mesh)):
    y_ep, _ = jax.jit(lambda p, x: moe.moe_apply(p, x, cfg))(p, x)
err = float(jnp.abs(y_ep - y_ref).max())
assert err < 1e-4, err
print("ok", err)
""")


def test_distributed_train_step_and_elastic_restore(tmp_path):
    out = run_child(f"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_rules
from repro.sharding.specs import param_shardings, use_sharding
from repro.train.train_step import TrainHParams, init_train_state, make_train_step
from repro.models.api import synth_batch
from repro.checkpoint import ckpt as ckpt_lib
cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
mesh = make_host_mesh(2, 4)
rules = make_rules(mesh)
hp = TrainHParams(remat="full", grad_accum=2)
step = make_train_step(cfg, hp)
state = init_train_state(jax.random.PRNGKey(0), cfg)
psh = param_shardings(state["params"], mesh, rules)
state = dict(params=jax.device_put(state["params"], psh),
             opt=dict(m=jax.device_put(state["opt"]["m"], psh),
                      v=jax.device_put(state["opt"]["v"], psh),
                      step=state["opt"]["step"]))
batch = synth_batch(0, cfg, 4, 64)
with use_sharding(mesh, rules):
    jstep = jax.jit(step)
    state, m1 = jstep(state, batch)
    state, m2 = jstep(state, batch)
assert np.isfinite(float(m2["loss"]))
ckpt_lib.save({json.dumps(str(tmp_path))}, 2, state)
print(json.dumps(dict(ok=True, loss=float(m2["loss"]))))
""")
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["ok"]
    # elastic: restore the 8-device checkpoint on 4 devices
    run_child(f"""
import jax, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_rules
from repro.sharding.specs import param_shardings
from repro.train.train_step import init_train_state
from repro.checkpoint import ckpt as ckpt_lib
cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
mesh = make_host_mesh(2, 2)
rules = make_rules(mesh)
state = init_train_state(jax.random.PRNGKey(0), cfg)
psh = param_shardings(state["params"], mesh, rules)
shardings = dict(params=psh, opt=dict(m=psh, v=psh, step=None))
restored, meta = ckpt_lib.restore({json.dumps(str(tmp_path))}, state,
                                  shardings=shardings)
assert meta["step"] == 2
print("elastic ok")
""", devices=4)
