"""Multi-device paths (shard_map engines, EP MoE, distributed train step).

Device count is locked at jax init, so these run in subprocesses with
forced host devices."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_boruvka_multidevice_exact():
    out = run_child("""
import numpy as np, jax, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.boruvka_dist import minimum_spanning_forest
mesh = make_mesh((8,), ("x",))
g = generators.generate("rmat", 10, seed=3)
want = kruskal_ref.kruskal(g)
got, stats = minimum_spanning_forest(g, mesh=mesh)
assert np.array_equal(got.edge_mask, want.edge_mask)
print(json.dumps(dict(ok=True, rounds=stats.rounds)))
""")
    assert json.loads(out.strip().splitlines()[-1])["ok"]


def test_boruvka_round_kernel_pallas_1_2_4_shards():
    """The fused round body (round_kernel="pallas", DESIGN.md §9) stays
    bit-identical to the Kruskal oracle AND to the XLA chain on 1/2/4
    shards — the replicated canonical bitmap + single-collective round must
    not depend on the shard count."""
    out = run_child("""
import numpy as np, jax, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.boruvka_dist import minimum_spanning_forest
from repro.core.params import GHSParams
g = generators.generate("rmat", 9, seed=3)
want = kruskal_ref.kruskal(g)
rows = []
for shards in (1, 2, 4):
    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    masks = {}
    for rk in ("xla", "pallas"):
        got, st = minimum_spanning_forest(
            g, params=GHSParams(round_kernel=rk), mesh=mesh)
        masks[rk] = got.edge_mask
        assert np.array_equal(got.edge_mask, want.edge_mask), (shards, rk)
    assert np.array_equal(masks["xla"], masks["pallas"]), shards
    rows.append(shards)
print(json.dumps(dict(ok=True, shards=rows)))
""", devices=4)
    assert json.loads(out.strip().splitlines()[-1])["ok"]


def test_ghs_multidevice_exact():
    out = run_child("""
import numpy as np, jax, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.ghs_message import minimum_spanning_forest
mesh = make_mesh((4,), ("x",))
g = generators.generate("rmat", 7, seed=5)
want = kruskal_ref.kruskal(g)
got, stats = minimum_spanning_forest(g, mesh=mesh)
assert np.array_equal(got.edge_mask, want.edge_mask)
print(json.dumps(dict(ok=True, steps=stats.supersteps,
                      remote=stats.sent_remote)))
""", devices=4)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["ok"] and rec["remote"] > 0   # real cross-shard traffic


def test_ghs_runtime_ablation_matrix_1_2_4_shards():
    """Engine equivalence under the shared runtime: relaxed vs FIFO Test
    queue, compressed vs uncompressed messages, and hash/linear/binary
    lookup all produce bit-identical forests across 1/2/4 shards."""
    out = run_child("""
import numpy as np, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.ghs_message import minimum_spanning_forest
from repro.core.params import GHSParams

ABLATIONS = [
    ("fifo",     GHSParams(relaxed_test_queue=False)),
    ("relaxed",  GHSParams(relaxed_test_queue=True)),
    ("raw",      GHSParams(compress_messages=False)),
    ("packed",   GHSParams(compress_messages=True)),
    ("hash",     GHSParams(use_hashing=True)),
    ("linear",   GHSParams(use_hashing=False)),
    ("binary",   GHSParams(use_hashing=False, hash_table_factor=-1.0)),
]
g = generators.generate("rmat", 6, seed=9)
want = kruskal_ref.kruskal(g)
rows = []
for shards in (1, 2, 4):
    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    for name, params in ABLATIONS:
        got, st = minimum_spanning_forest(g, params=params, mesh=mesh)
        rows.append(dict(
            shards=shards, name=name,
            ok=bool(np.array_equal(got.edge_mask, want.edge_mask)),
            sync_ok=bool(st.host_syncs == st.intervals + 1)))
print(json.dumps(rows))
""", devices=4)
    rows = json.loads(out.strip().splitlines()[-1])
    assert len(rows) == 21
    bad = [r for r in rows if not (r["ok"] and r["sync_ok"])]
    assert not bad, bad


def test_ghs_queue_overflow_raises():
    """ERR_QUEUE_OVERFLOW surfaces as a RuntimeError on both drivers —
    never a silently wrong forest — and the message NAMES the flag and the
    knob that fixes it (not just a bare hex code).  A cross-shard star
    floods shard 0's rings when the capacity override is small; the same
    graph converges bit-identically at the default (auto-sized) capacity."""
    out = run_child("""
import numpy as np, json
from repro.compat import make_mesh
from repro.core import kruskal_ref
from repro.core.graph import preprocess
from repro.core.ghs_message import minimum_spanning_forest
from repro.core.params import GHSParams

mesh = make_mesh((2,), ("x",))
n = 256
src = np.zeros(n - 1, np.int64)
dst = np.arange(1, n, dtype=np.int64)
rng = np.random.default_rng(0)
w = rng.random(n - 1, dtype=np.float32) * 0.9 + 0.05
g = preprocess(src, dst, w, n)
res = dict(msg={}, ok={})
for loop in ("device", "host"):
    try:
        minimum_spanning_forest(
            g, mesh=mesh,
            params=GHSParams(queue_capacity=160, round_loop=loop))
        res["msg"][loop] = ""
    except RuntimeError as e:
        res["msg"][loop] = str(e)
    got, _ = minimum_spanning_forest(
        g, mesh=mesh, params=GHSParams(round_loop=loop))
    res["ok"][loop] = bool(np.array_equal(
        got.edge_mask, kruskal_ref.kruskal(g).edge_mask))
print(json.dumps(res))
""", devices=2)
    rec = json.loads(out.strip().splitlines()[-1])
    for loop in ("device", "host"):
        msg = rec["msg"][loop]
        assert "error flags" in msg, (loop, msg)
        assert "ERR_QUEUE_OVERFLOW" in msg, (loop, msg)
        assert "queue_capacity" in msg, (loop, msg)
    assert rec["ok"] == {"device": True, "host": True}


def test_partitioners_and_pipeline_multidevice():
    """DESIGN.md §7 acceptance: every partitioner yields the exact Kruskal
    forest on 1/2/4 shards (both engines), and the device pipeline feeds
    the Borůvka engine shard-resident edges that elect the same forest."""
    out = run_child("""
import numpy as np, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref, pipeline
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams
from repro.core.pipeline import GraphSpec

g = generators.generate("rmat", 8, seed=9)
want = kruskal_ref.kruskal(g)
gg = generators.generate("rmat", 6, seed=9)
want_g = kruskal_ref.kruskal(gg)
spec = GraphSpec("rmat", 9, seed=4)
want_p = kruskal_ref.kruskal(pipeline.build_host(spec))
rows = []
for shards in (1, 2, 4):
    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    for part in ("block", "hashed", "balanced"):
        got, st = minimum_spanning_forest(
            g, method="boruvka", params=GHSParams(partitioner=part),
            mesh=mesh)
        rows.append(dict(
            shards=shards, part=part, engine="boruvka",
            ok=bool(np.array_equal(got.edge_mask, want.edge_mask)),
            sync_ok=bool(st.host_syncs == st.intervals + 1)))
        got, st = minimum_spanning_forest(
            gg, method="ghs", params=GHSParams(partitioner=part), mesh=mesh)
        rows.append(dict(
            shards=shards, part=part, engine="ghs",
            ok=bool(np.array_equal(got.edge_mask, want_g.edge_mask)),
            sync_ok=bool(st.host_syncs == st.intervals + 1)))
    dev = pipeline.build(spec, mesh=mesh)
    got, st = minimum_spanning_forest(dev, method="boruvka", mesh=mesh)
    rows.append(dict(
        shards=shards, part="block", engine="boruvka-deviceedges",
        ok=bool(np.array_equal(got.edge_mask, want_p.edge_mask)),
        sync_ok=bool(st.host_syncs == st.intervals + 1)))
print(json.dumps(rows))
""", devices=4)
    rows = json.loads(out.strip().splitlines()[-1])
    assert len(rows) == 3 * (3 * 2 + 1)
    bad = [r for r in rows if not (r["ok"] and r["sync_ok"])]
    assert not bad, bad


def test_ep_moe_matches_ragged_when_dropfree():
    run_child("""
import jax, jax.numpy as jnp
from repro.models import moe, moe_ep
from repro.launch.mesh import make_host_mesh, make_rules
from repro.sharding.specs import use_sharding
from repro.models.config import ModelConfig
moe_ep.capacity = lambda tokens, cfg, e_pad: tokens   # drop-free
cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=128, n_experts=16, top_k=2,
                  d_expert=32, n_shared=1, d_shared=64,
                  compute_dtype="float32")
p = moe.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 64))
y_ref, _ = moe.moe_apply(p, x, cfg)
mesh = make_host_mesh(2, 4)
with use_sharding(mesh, make_rules(mesh)):
    y_ep, _ = jax.jit(lambda p, x: moe.moe_apply(p, x, cfg))(p, x)
err = float(jnp.abs(y_ep - y_ref).max())
assert err < 1e-4, err
print("ok", err)
""")


def test_distributed_train_step_and_elastic_restore(tmp_path):
    out = run_child(f"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_rules
from repro.sharding.specs import param_shardings, use_sharding
from repro.train.train_step import TrainHParams, init_train_state, make_train_step
from repro.models.api import synth_batch
from repro.checkpoint import ckpt as ckpt_lib
cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
mesh = make_host_mesh(2, 4)
rules = make_rules(mesh)
hp = TrainHParams(remat="full", grad_accum=2)
step = make_train_step(cfg, hp)
state = init_train_state(jax.random.PRNGKey(0), cfg)
psh = param_shardings(state["params"], mesh, rules)
state = dict(params=jax.device_put(state["params"], psh),
             opt=dict(m=jax.device_put(state["opt"]["m"], psh),
                      v=jax.device_put(state["opt"]["v"], psh),
                      step=state["opt"]["step"]))
batch = synth_batch(0, cfg, 4, 64)
with use_sharding(mesh, rules):
    jstep = jax.jit(step)
    state, m1 = jstep(state, batch)
    state, m2 = jstep(state, batch)
assert np.isfinite(float(m2["loss"]))
ckpt_lib.save({json.dumps(str(tmp_path))}, 2, state)
print(json.dumps(dict(ok=True, loss=float(m2["loss"]))))
""")
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["ok"]
    # elastic: restore the 8-device checkpoint on 4 devices
    run_child(f"""
import jax, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_rules
from repro.sharding.specs import param_shardings
from repro.train.train_step import init_train_state
from repro.checkpoint import ckpt as ckpt_lib
cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
mesh = make_host_mesh(2, 2)
rules = make_rules(mesh)
state = init_train_state(jax.random.PRNGKey(0), cfg)
psh = param_shardings(state["params"], mesh, rules)
shardings = dict(params=psh, opt=dict(m=psh, v=psh, step=None))
restored, meta = ckpt_lib.restore({json.dumps(str(tmp_path))}, state,
                                  shardings=shardings)
assert meta["step"] == 2
print("elastic ok")
""", devices=4)
