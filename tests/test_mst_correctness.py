"""Both MST engines vs the Kruskal oracle — edge-set-exact equality."""
import numpy as np
import pytest

from repro.core import generators, kruskal_ref
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams


@pytest.mark.parametrize("kind", ["rmat", "ssca2", "random", "disconnected"])
def test_boruvka_matches_kruskal(kind):
    g = generators.generate(kind, 9, seed=11)
    want = kruskal_ref.kruskal(g)
    got, stats = minimum_spanning_forest(g, method="boruvka")
    assert np.array_equal(got.edge_mask, want.edge_mask)
    assert got.num_components == want.num_components
    assert stats.rounds >= 1


@pytest.mark.parametrize("kind", ["rmat", "disconnected"])
def test_ghs_matches_kruskal(kind):
    g = generators.generate(kind, 7, seed=3)
    want = kruskal_ref.kruskal(g)
    got, stats = minimum_spanning_forest(g, method="ghs")
    assert np.array_equal(got.edge_mask, want.edge_mask)
    assert got.num_components == want.num_components
    assert stats.halted_fragments >= 1


def test_numpy_boruvka_matches_kruskal():
    g = generators.generate("random", 11, seed=5)
    want = kruskal_ref.kruskal(g)
    got = kruskal_ref.boruvka_numpy(g)
    assert np.array_equal(got.edge_mask, want.edge_mask)


@pytest.mark.parametrize("params", [
    GHSParams(use_hashing=False, relaxed_test_queue=False,
              compress_messages=False),                       # base version
    GHSParams(use_hashing=False, hash_table_factor=-1.0,
              relaxed_test_queue=False),                      # binary search
    GHSParams(relaxed_test_queue=True, check_frequency=1),
    GHSParams(relaxed_test_queue=True, check_frequency=7),
    GHSParams(),                                              # final version
])
def test_ghs_ablations_all_exact(params):
    g = generators.generate("rmat", 7, seed=9)
    want = kruskal_ref.kruskal(g)
    got, _ = minimum_spanning_forest(g, method="ghs", params=params)
    assert np.array_equal(got.edge_mask, want.edge_mask)


def test_duplicate_weights_tiebreak():
    """C6: identical weights are resolved by the unique edge-id lane."""
    rng = np.random.default_rng(0)
    n, m = 128, 1024
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.choice(np.asarray([0.25, 0.5, 0.75], np.float32), m)  # collisions
    from repro.core.graph import preprocess
    g = preprocess(src, dst, w, n)
    want = kruskal_ref.kruskal(g)
    got_b, _ = minimum_spanning_forest(g, method="boruvka")
    got_g, _ = minimum_spanning_forest(g, method="ghs")
    assert np.array_equal(got_b.edge_mask, want.edge_mask)
    assert np.array_equal(got_g.edge_mask, want.edge_mask)


def test_single_vertex_and_empty():
    from repro.core.graph import preprocess
    g = preprocess(np.zeros(0), np.zeros(0), np.zeros(0, np.float32), 4)
    got, _ = minimum_spanning_forest(g, method="boruvka")
    assert got.num_tree_edges == 0
    assert got.num_components == 4


def test_boruvka_pallas_segmin_path():
    """Engine with the Pallas segment-min kernel is bit-identical."""
    from repro.core.params import GHSParams
    g = generators.generate("rmat", 8, seed=21)
    want, _ = minimum_spanning_forest(g, method="boruvka")
    got, _ = minimum_spanning_forest(
        g, method="boruvka", params=GHSParams(use_pallas=True))
    assert np.array_equal(got.edge_mask, want.edge_mask)


def test_round_loop_host_vs_device_identical():
    """The fused device loop and the legacy host loop elect the same forest."""
    for kind, seed in [("rmat", 13), ("disconnected", 2)]:
        g = generators.generate(kind, 9, seed=seed)
        want = kruskal_ref.kruskal(g)
        host, _ = minimum_spanning_forest(
            g, method="boruvka", params=GHSParams(round_loop="host"))
        dev, _ = minimum_spanning_forest(
            g, method="boruvka", params=GHSParams(round_loop="device"))
        assert np.array_equal(host.edge_mask, want.edge_mask)
        assert np.array_equal(dev.edge_mask, want.edge_mask)
        assert host.total_weight == dev.total_weight


def test_compaction_pow2_bit_identical():
    """On-device pow2 compaction every round leaves the forest bit-identical
    to the no-compaction run and the Kruskal oracle (multi-round graph)."""
    g = generators.generate("rmat", 9, seed=7)
    want = kruskal_ref.kruskal(g)
    compacted, st_c = minimum_spanning_forest(
        g, method="boruvka",
        params=GHSParams(compaction="pow2", check_frequency=1))
    plain, st_p = minimum_spanning_forest(
        g, method="boruvka", params=GHSParams(compaction="none"))
    assert st_p.rounds > 1, "need a multi-round graph for this test"
    assert st_c.compactions >= 1, "compaction path was not exercised"
    assert np.array_equal(compacted.edge_mask, want.edge_mask)
    assert np.array_equal(plain.edge_mask, want.edge_mask)
    assert compacted.total_weight == plain.total_weight
    assert compacted.num_components == want.num_components


def test_device_loop_host_sync_contract():
    """≤ 1 host sync per compaction interval (+ the final state fetch)."""
    g = generators.generate("rmat", 9, seed=11)
    _, st = minimum_spanning_forest(
        g, method="boruvka", params=GHSParams(round_loop="device"))
    assert st.intervals >= 1
    assert st.host_syncs == st.intervals + 1


@pytest.mark.parametrize("method", ["boruvka", "ghs", "filter_boruvka"])
@pytest.mark.parametrize("depth", [0, 1])
def test_sync_contract_cross_engine(method, depth):
    """The REAL EngineStats invariant, asserted across every engine and
    both interval-pipeline depths: ``host_syncs == intervals +
    extra_syncs``.  interval_loop books interval readbacks into host_syncs
    and intervals in lockstep; every OTHER blocking transfer an engine
    makes (final state fetches, filter keep-mask fetches, legacy re-uploads)
    must book into BOTH host_syncs and extra_syncs.  (The docstring used to
    promise ``host_syncs == intervals + 1``, which only the single-graph
    device loops honor.)"""
    g = generators.generate("rmat", 7, seed=5)
    want = kruskal_ref.kruskal(g)
    res, st = minimum_spanning_forest(
        g, method=method, params=GHSParams(interval_pipeline=depth))
    assert np.array_equal(res.edge_mask, want.edge_mask)
    assert st.intervals >= 1
    assert st.extra_syncs >= 1
    assert st.host_syncs == st.intervals + st.extra_syncs
    if method in ("boruvka", "ghs"):
        # Single-graph device loops: the +1 is exactly the final fetch.
        assert st.extra_syncs == 1


@pytest.mark.parametrize("method", ["boruvka", "ghs"])
def test_sync_contract_legacy_host_loops(method):
    """The same invariant holds on the legacy host-driven loops, where
    extra_syncs additionally counts per-round readbacks and compaction
    re-uploads."""
    g = generators.generate("rmat", 7, seed=5)
    want = kruskal_ref.kruskal(g)
    res, st = minimum_spanning_forest(
        g, method=method, params=GHSParams(round_loop="host"))
    assert np.array_equal(res.edge_mask, want.edge_mask)
    assert st.host_syncs == st.intervals + st.extra_syncs


def test_ghs_round_loop_host_vs_device_identical():
    """The fused device superstep loop and the legacy per-superstep driver
    run the same supersteps and elect the same forest; the device loop's
    host syncs scale with check_frequency intervals, not supersteps."""
    for kind, seed in [("rmat", 13), ("disconnected", 2)]:
        g = generators.generate(kind, 7, seed=seed)
        want = kruskal_ref.kruskal(g)
        host, sh = minimum_spanning_forest(
            g, method="ghs", params=GHSParams(round_loop="host"))
        dev, sd = minimum_spanning_forest(
            g, method="ghs", params=GHSParams(round_loop="device"))
        assert np.array_equal(host.edge_mask, want.edge_mask)
        assert np.array_equal(dev.edge_mask, want.edge_mask)
        assert sd.supersteps == sh.supersteps
        # runtime protocol: one fused readback per interval + final fetch
        assert sd.host_syncs == sd.intervals + 1
        assert sh.host_syncs == sh.supersteps + 1
        check = max(GHSParams().check_frequency, 1)
        assert sd.intervals <= -(-sd.supersteps // check) + 1
        assert sd.intervals < sh.intervals


def test_ghs_empty_iter_cnt_to_break_semantics():
    """Paper §3.6: silence must persist ``empty_iter_cnt_to_break``
    consecutive checks before halting — a non-default value adds exactly
    that many confirmation supersteps and never changes the forest."""
    g = generators.generate("rmat", 7, seed=9)
    want = kruskal_ref.kruskal(g)
    for loop in ("device", "host"):
        base, s1 = minimum_spanning_forest(
            g, method="ghs",
            params=GHSParams(round_loop=loop, empty_iter_cnt_to_break=1))
        conf, s4 = minimum_spanning_forest(
            g, method="ghs",
            params=GHSParams(round_loop=loop, empty_iter_cnt_to_break=4))
        assert s4.supersteps == s1.supersteps + 3, loop
        assert np.array_equal(base.edge_mask, want.edge_mask)
        assert np.array_equal(conf.edge_mask, want.edge_mask)


def test_ghs_history_device_matches_host():
    """The on-device per-superstep history buffers reproduce the legacy
    driver's per-step queue/bytes series exactly (Fig 3/4 inputs)."""
    g = generators.generate("rmat", 7, seed=5)
    _, sd = minimum_spanning_forest(
        g, method="ghs", params=GHSParams(round_loop="device"),
        collect_history=True)
    _, sh = minimum_spanning_forest(
        g, method="ghs", params=GHSParams(round_loop="host"),
        collect_history=True)
    assert len(sd.queue_history) == sd.supersteps
    assert sd.queue_history == sh.queue_history
    assert sd.bytes_history == sh.bytes_history
    assert sd.queue_history[-1] == 0          # terminal silence
    assert sd.bytes_history[-1] == sd.bytes_remote


# ---------------------------------------------------------------------------
# Adversarial corpus: the degenerate inputs generators rarely emit.  Every
# case runs through BOTH engines (the Borůvka engine under both loop
# drivers) and the Kruskal oracle, edge-set-exactly.
# ---------------------------------------------------------------------------

def _adversarial_corpus():
    from repro.core.graph import preprocess
    rng = np.random.default_rng(42)

    # Self-loops: every vertex loops on itself, plus a sparse real graph —
    # §3.1 must drop every loop and the engines must agree on the rest.
    n = 64
    loops = np.arange(n)
    src = np.concatenate([loops, rng.integers(0, n, 160)])
    dst = np.concatenate([loops, rng.integers(0, n, 160)])
    w = rng.random(src.size, dtype=np.float32) * 0.9 + 0.05
    yield "self-loops", preprocess(src, dst, w, n)

    # Duplicate / parallel edges: every pair sampled many times in both
    # directions with different weights — dedup must keep the min copy and
    # the forest must be built over the deduped canonical ids.
    base_u = rng.integers(0, 32, 48)
    base_v = rng.integers(0, 32, 48)
    src = np.tile(np.concatenate([base_u, base_v]), 4)
    dst = np.tile(np.concatenate([base_v, base_u]), 4)
    w = rng.random(src.size, dtype=np.float32) * 0.9 + 0.05
    yield "parallel-edges", preprocess(src, dst, w, 32)

    # All-equal weights: the election is decided ENTIRELY by the canonical
    # edge-id lane of the packed key (C6 tie-break).
    src = rng.integers(0, 48, 300)
    dst = rng.integers(0, 48, 300)
    w = np.full(300, np.float32(0.5))
    yield "all-equal-weights", preprocess(src, dst, w, 48)

    # Fully disconnected vertex set: no edges at all — the forest is empty
    # and every vertex is its own component.
    yield "no-edges", preprocess(
        np.zeros(0), np.zeros(0), np.zeros(0, np.float32), 37)

    # Single-edge graph (plus isolated vertices): one tree edge, n-1
    # components.
    yield "single-edge", preprocess(
        np.array([2]), np.array([5]), np.array([0.25], np.float32), 9)


@pytest.mark.parametrize(
    "name,g", list(_adversarial_corpus()),
    ids=[name for name, _ in _adversarial_corpus()])
def test_adversarial_corpus_both_engines_exact(name, g):
    want = kruskal_ref.kruskal(g)
    for params in (GHSParams(round_loop="device"),
                   GHSParams(round_loop="host"),
                   # Fused round body (DESIGN.md §9): sort/scatter lowering
                   # and the Pallas interpret kernels, same corpus.
                   GHSParams(round_kernel="pallas"),
                   GHSParams(round_kernel="pallas", use_pallas=True)):
        got, _ = minimum_spanning_forest(g, method="boruvka", params=params)
        assert np.array_equal(got.edge_mask, want.edge_mask), \
            (name, params.round_loop, params.round_kernel)
        assert got.num_components == want.num_components
        assert got.total_weight == want.total_weight
    got, _ = minimum_spanning_forest(g, method="ghs")
    assert np.array_equal(got.edge_mask, want.edge_mask), name
    assert got.num_components == want.num_components


def test_adversarial_corpus_batched_exact():
    """The whole corpus as ONE mixed batch: every lane oracle-exact and
    bit-identical to its single-graph solve — under both round kernels."""
    from repro.core.mst_api import minimum_spanning_forests
    names, graphs = zip(*_adversarial_corpus())
    for rk in ("xla", "pallas"):
        results, stats = minimum_spanning_forests(
            list(graphs), params=GHSParams(round_kernel=rk))
        assert len(stats.rounds_per_graph) == len(graphs)
        for name, g, got in zip(names, graphs, results):
            want = kruskal_ref.kruskal(g)
            single, _ = minimum_spanning_forest(g, method="boruvka")
            assert np.array_equal(got.edge_mask, want.edge_mask), (name, rk)
            assert np.array_equal(got.edge_mask, single.edge_mask), (name, rk)
            assert got.num_components == want.num_components, (name, rk)


def test_round_kernel_pallas_identical_and_validated():
    """round_kernel="pallas" matches the oracle and the XLA chain on the
    paper generators, and the knob itself is validated."""
    for kind in ("rmat", "disconnected"):
        g = generators.generate(kind, scale=8, seed=5)
        want = kruskal_ref.kruskal(g)
        gx, _ = minimum_spanning_forest(
            g, params=GHSParams(round_kernel="xla"))
        gp, stp = minimum_spanning_forest(
            g, params=GHSParams(round_kernel="pallas", check_frequency=3))
        assert np.array_equal(gp.edge_mask, want.edge_mask), kind
        assert np.array_equal(gp.edge_mask, gx.edge_mask), kind
        # The fused loop keeps the runtime sync contract.
        assert stp.host_syncs == stp.intervals + 1
    with pytest.raises(ValueError, match="round_kernel"):
        minimum_spanning_forest(
            g, params=GHSParams(round_kernel="mosaic"))


def test_padding_inert_when_vertex0_isolated():
    """Regression for the _pad_pow2 fill bug class: padding edges must be
    self-loops by construction.  Vertex 0 has no incident edges; if padded
    src/dst slots were filled with vertex 0 and their weight lane ever
    participated, vertex 0 could be hooked into a fragment."""
    from repro.core.graph import preprocess
    rng = np.random.default_rng(3)
    n = 130                       # not a power of two → padding is exercised
    m = 500
    src = rng.integers(1, n, m)   # vertex 0 never appears
    dst = rng.integers(1, n, m)
    w = rng.random(m, dtype=np.float32) * 0.98 + 0.01
    g = preprocess(src, dst, w, n)
    assert not np.any(g.src == 0) and not np.any(g.dst == 0)
    want = kruskal_ref.kruskal(g)
    for params in (GHSParams(round_loop="device", check_frequency=1),
                   GHSParams(round_loop="host")):
        got, _ = minimum_spanning_forest(g, method="boruvka", params=params)
        assert np.array_equal(got.edge_mask, want.edge_mask)
        assert got.num_components == want.num_components
        # vertex 0 must remain isolated: no tree edge touches it
        assert not np.any(got.edge_mask & ((g.src == 0) | (g.dst == 0)))
