"""Both MST engines vs the Kruskal oracle — edge-set-exact equality."""
import numpy as np
import pytest

from repro.core import generators, kruskal_ref
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams


@pytest.mark.parametrize("kind", ["rmat", "ssca2", "random", "disconnected"])
def test_boruvka_matches_kruskal(kind):
    g = generators.generate(kind, 9, seed=11)
    want = kruskal_ref.kruskal(g)
    got, stats = minimum_spanning_forest(g, method="boruvka")
    assert np.array_equal(got.edge_mask, want.edge_mask)
    assert got.num_components == want.num_components
    assert stats.rounds >= 1


@pytest.mark.parametrize("kind", ["rmat", "disconnected"])
def test_ghs_matches_kruskal(kind):
    g = generators.generate(kind, 7, seed=3)
    want = kruskal_ref.kruskal(g)
    got, stats = minimum_spanning_forest(g, method="ghs")
    assert np.array_equal(got.edge_mask, want.edge_mask)
    assert got.num_components == want.num_components
    assert stats.halted_fragments >= 1


def test_numpy_boruvka_matches_kruskal():
    g = generators.generate("random", 11, seed=5)
    want = kruskal_ref.kruskal(g)
    got = kruskal_ref.boruvka_numpy(g)
    assert np.array_equal(got.edge_mask, want.edge_mask)


@pytest.mark.parametrize("params", [
    GHSParams(use_hashing=False, relaxed_test_queue=False,
              compress_messages=False),                       # base version
    GHSParams(use_hashing=False, hash_table_factor=-1.0,
              relaxed_test_queue=False),                      # binary search
    GHSParams(relaxed_test_queue=True, check_frequency=1),
    GHSParams(relaxed_test_queue=True, check_frequency=7),
    GHSParams(),                                              # final version
])
def test_ghs_ablations_all_exact(params):
    g = generators.generate("rmat", 7, seed=9)
    want = kruskal_ref.kruskal(g)
    got, _ = minimum_spanning_forest(g, method="ghs", params=params)
    assert np.array_equal(got.edge_mask, want.edge_mask)


def test_duplicate_weights_tiebreak():
    """C6: identical weights are resolved by the unique edge-id lane."""
    rng = np.random.default_rng(0)
    n, m = 128, 1024
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.choice(np.asarray([0.25, 0.5, 0.75], np.float32), m)  # collisions
    from repro.core.graph import preprocess
    g = preprocess(src, dst, w, n)
    want = kruskal_ref.kruskal(g)
    got_b, _ = minimum_spanning_forest(g, method="boruvka")
    got_g, _ = minimum_spanning_forest(g, method="ghs")
    assert np.array_equal(got_b.edge_mask, want.edge_mask)
    assert np.array_equal(got_g.edge_mask, want.edge_mask)


def test_single_vertex_and_empty():
    from repro.core.graph import preprocess
    g = preprocess(np.zeros(0), np.zeros(0), np.zeros(0, np.float32), 4)
    got, _ = minimum_spanning_forest(g, method="boruvka")
    assert got.num_tree_edges == 0
    assert got.num_components == 4


def test_boruvka_pallas_segmin_path():
    """Engine with the Pallas segment-min kernel is bit-identical."""
    from repro.core.params import GHSParams
    g = generators.generate("rmat", 8, seed=21)
    want, _ = minimum_spanning_forest(g, method="boruvka")
    got, _ = minimum_spanning_forest(
        g, method="boruvka", params=GHSParams(use_pallas=True))
    assert np.array_equal(got.edge_mask, want.edge_mask)
