"""Filter-Borůvka hybrid (DESIGN.md §10): bit-identity, sampler contract,
connectivity probe, empty-sample guarantee, shard-count invariance."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import generators, kruskal_ref, pipeline
from repro.core.filter_boruvka import MAX_PASSES
from repro.core.graph import PAD_VERTEX, Graph, preprocess
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams
from repro.kernels.spmv_minplus import ops as minplus_ops

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, devices: int = 4) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def _assert_identical(got, want, g, ctx):
    assert np.array_equal(got.edge_mask, want.edge_mask), ctx
    # weight multiset equality (bit-exact, via the raw float32 patterns)
    assert np.array_equal(
        np.sort(g.weight[got.edge_mask].view(np.uint32)),
        np.sort(g.weight[want.edge_mask].view(np.uint32))), ctx
    assert got.num_components == want.num_components, ctx
    assert got.num_tree_edges == want.num_tree_edges, ctx


# ---------------------------------------------------------------------------
# Bit-identity: oracle + plain engine, generated + adversarial graphs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["rmat", "random", "disconnected"])
@pytest.mark.parametrize("rate", [0.0, 0.1, 0.5, 1.0])
def test_filter_matches_kruskal_and_boruvka(kind, rate):
    g = generators.generate(kind, 8, seed=11)
    want = kruskal_ref.kruskal(g)
    plain, _ = minimum_spanning_forest(g, method="boruvka")
    got, st = minimum_spanning_forest(
        g, method="filter_boruvka",
        params=GHSParams(filter_sample_rate=rate))
    _assert_identical(got, want, g, (kind, rate))
    _assert_identical(got, plain, g, (kind, rate))
    assert 1 <= st.filter_passes <= MAX_PASSES
    assert st.edges_filtered == g.num_edges - st.survivor_history[-1]


def test_adversarial_corpus_filter_exact():
    from test_mst_correctness import _adversarial_corpus
    for name, g in _adversarial_corpus():
        want = kruskal_ref.kruskal(g)
        for rate in (0.0, 0.4, 1.0):
            got, _ = minimum_spanning_forest(
                g, method="filter_boruvka",
                params=GHSParams(filter_sample_rate=rate))
            _assert_identical(got, want, g, (name, rate))


def test_filter_levels_sweep_identical():
    """The level count quantizes the cycle rule — it may only change how
    many edges are dropped, never the forest."""
    g = generators.generate("rmat", 9, seed=4)
    want = kruskal_ref.kruskal(g)
    filtered = []
    for levels in (1, 2, 16, 64):
        got, st = minimum_spanning_forest(
            g, method="filter_boruvka",
            params=GHSParams(filter_sample_rate=0.25,
                             filter_levels=levels))
        _assert_identical(got, want, g, levels)
        filtered.append(st.edges_filtered)
    # more levels → a sharper path-max bound → monotone non-decreasing drops
    assert filtered == sorted(filtered)


def test_filter_knob_validation():
    g = generators.generate("rmat", 6, seed=0)
    with pytest.raises(ValueError, match="filter_levels"):
        minimum_spanning_forest(g, method="filter_boruvka",
                                params=GHSParams(filter_levels=0))


def test_filter_recursion_bound():
    """A tiny threshold forces the recursion; it still runs at most
    MAX_PASSES sample→solve→filter passes and stays exact."""
    g = generators.generate("random", 8, seed=2)
    want = kruskal_ref.kruskal(g)
    got, st = minimum_spanning_forest(
        g, method="filter_boruvka",
        params=GHSParams(filter_sample_rate=0.2, filter_threshold=1))
    _assert_identical(got, want, g, "recursion")
    assert st.filter_passes == MAX_PASSES
    assert len(st.survivor_history) == MAX_PASSES


# ---------------------------------------------------------------------------
# Empty-sample guarantee (satellite: p=0 regression)
# ---------------------------------------------------------------------------

def test_empty_sample_keeps_isolated_vertex_bridge():
    """With p=0 the Bernoulli sample is empty: the sampler must never have
    dropped anything — the final solve sees the FULL edge set, including
    the single bridge that connects an otherwise-isolated vertex."""
    rng = np.random.default_rng(7)
    n = 40
    src = rng.integers(0, n - 1, 300)
    dst = rng.integers(0, n - 1, 300)
    w = rng.random(300, dtype=np.float32) * 0.9 + 0.05
    # vertex n-1 hangs off the graph by exactly one (heavy) edge
    src = np.concatenate([src, [0]])
    dst = np.concatenate([dst, [n - 1]])
    w = np.concatenate([w, np.float32([0.99])])
    g = preprocess(src, dst, w, n)
    bridge = np.flatnonzero((g.src == 0) & (g.dst == n - 1))
    assert bridge.size == 1

    want = kruskal_ref.kruskal(g)
    got, st = minimum_spanning_forest(
        g, method="filter_boruvka",
        params=GHSParams(filter_sample_rate=0.0))
    _assert_identical(got, want, g, "p=0")
    assert got.edge_mask[bridge[0]]          # the bridge is in the forest
    assert st.edges_filtered == 0            # nothing was dropped...
    assert st.survivor_history == (g.num_edges,)  # ...full survivor set
    assert st.filter_passes == 1


# ---------------------------------------------------------------------------
# Sampler contract
# ---------------------------------------------------------------------------

def test_sampler_numpy_jnp_identical_and_slice_invariant():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    eid = np.arange(5000, dtype=np.uint64)
    m_np = np.asarray(pipeline.sample_mask(3, 0.37, eid))
    with enable_x64():
        m_j = np.asarray(pipeline.sample_mask(3, 0.37, jnp.asarray(eid)))
    assert np.array_equal(m_np, m_j)
    # per-edge decisions do not depend on which shard holds the edge:
    # any slicing of the id space reproduces the same bits
    parts = [pipeline.sample_mask(3, 0.37, eid[i::4]) for i in range(4)]
    rebuilt = np.empty_like(m_np)
    for i, p in enumerate(parts):
        rebuilt[i::4] = p
    assert np.array_equal(rebuilt, m_np)
    # endpoints are exact
    assert not pipeline.sample_mask(3, 0.0, eid).any()
    assert pipeline.sample_mask(3, 1.0, eid).all()
    # distinct seeds give distinct streams
    assert not np.array_equal(m_np, pipeline.sample_mask(4, 0.37, eid))
    # rate is honored within a loose tolerance
    assert abs(m_np.mean() - 0.37) < 0.05


def test_sampler_fixed_k_exact_size():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    eid = np.arange(700, dtype=np.uint64)
    m_np = pipeline.sample_mask_fixed_k(np, 5, 123, eid)
    with enable_x64():
        m_j = np.asarray(
            pipeline.sample_mask_fixed_k(jnp, 5, 123, jnp.asarray(eid)))
    assert np.array_equal(m_np, m_j)
    assert m_np.sum() == 123
    assert not pipeline.sample_mask_fixed_k(np, 5, 0, eid).any()
    assert pipeline.sample_mask_fixed_k(np, 5, 700, eid).all()


def test_sample_device_edges_matches_numpy():
    de = pipeline.build(pipeline.GraphSpec(kind="rmat", scale=7, seed=5),
                        None)
    got = np.asarray(pipeline.sample_device_edges(de, 0.3, seed=9))
    want = pipeline.sample_mask(
        9, 0.3, np.arange(de.num_edges, dtype=np.uint64))
    assert np.array_equal(got[:de.num_edges], want)
    assert not got[de.num_edges:].any()      # padding is never sampled


# ---------------------------------------------------------------------------
# Connectivity probe vs union-find oracle
# ---------------------------------------------------------------------------

def _oracle_labels(n, src, dst, active):
    dsu = kruskal_ref._DSU(n)
    for u, v, a in zip(src, dst, active):
        if a:
            dsu.union(int(u), int(v))
    return np.asarray([dsu.find(v) for v in range(n)])


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_connected_labels_matches_union_find(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 120))
    m = int(rng.integers(0, 400))
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    active = rng.random(m) < 0.6
    got = np.asarray(minplus_ops.connected_labels(
        src, dst, active, num_vertices=n))
    want = _oracle_labels(n, src, dst, active)
    # canonical labeling: every vertex labeled by its component's min id
    # (implies the partitions are equal)
    canon = np.empty(n, dtype=np.int64)
    for r in np.unique(want):
        members = np.flatnonzero(want == r)
        canon[members] = members.min()
    assert np.array_equal(got, canon)


def test_connected_labels_padding_inert():
    """PAD_VERTEX lanes with active=False must not perturb the labels."""
    src = np.asarray([0, 2, PAD_VERTEX, PAD_VERTEX], np.int32)
    dst = np.asarray([1, 3, PAD_VERTEX, PAD_VERTEX], np.int32)
    active = np.asarray([True, True, False, False])
    got = np.asarray(minplus_ops.connected_labels(
        src, dst, active, num_vertices=5))
    assert np.array_equal(got, [0, 0, 2, 2, 4])


def test_connected_labels_vmappable():
    """Batched probes (the per-level label build) share one compiled loop."""
    import jax
    src = np.asarray([0, 1, 2, 3], np.int32)
    dst = np.asarray([1, 2, 3, 4], np.int32)
    masks = np.asarray([[True, True, False, False],
                        [True, True, True, True],
                        [False, False, False, False]])
    got = np.asarray(jax.vmap(
        lambda a: minplus_ops.connected_labels(src, dst, a, num_vertices=5)
    )(masks))
    assert np.array_equal(got[0], [0, 0, 0, 3, 4])
    assert np.array_equal(got[1], [0, 0, 0, 0, 0])
    assert np.array_equal(got[2], np.arange(5))


# ---------------------------------------------------------------------------
# Shard sweep (subprocess: device count locks at jax init)
# ---------------------------------------------------------------------------

def test_filter_boruvka_1_2_4_shards_identical():
    out = run_child("""
import numpy as np, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams
g = generators.generate("rmat", 9, seed=3)
want = kruskal_ref.kruskal(g)
filtered = set()
for shards in (1, 2, 4):
    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    got, st = minimum_spanning_forest(
        g, method="filter_boruvka", mesh=mesh,
        params=GHSParams(filter_sample_rate=0.3, partitioner="hashed"))
    assert np.array_equal(got.edge_mask, want.edge_mask), shards
    filtered.add(st.edges_filtered)
# the filter decision set is shard-count invariant, not just the forest
assert len(filtered) == 1, filtered
print(json.dumps(dict(ok=True)))
""", devices=4)
    assert json.loads(out.strip().splitlines()[-1])["ok"]


# ---------------------------------------------------------------------------
# Property test (hypothesis): randomized graphs AND sample rates
# ---------------------------------------------------------------------------

def test_filter_property_randomized():
    pytest.importorskip(
        "hypothesis",
        reason="optional dev dependency (see requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st_

    @st_.composite
    def cases(draw):
        n = draw(st_.integers(min_value=2, max_value=48))
        m = draw(st_.integers(min_value=0, max_value=160))
        seed = draw(st_.integers(min_value=0, max_value=2**31 - 1))
        rate = draw(st_.floats(min_value=0.0, max_value=1.0))
        levels = draw(st_.integers(min_value=1, max_value=20))
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        w = rng.random(m, dtype=np.float32) * 0.98 + 0.01
        return preprocess(src, dst, w, n), rate, levels

    @settings(max_examples=25, deadline=None)
    @given(cases())
    def inner(case):
        g, rate, levels = case
        want = kruskal_ref.kruskal(g)
        plain, _ = minimum_spanning_forest(g, method="boruvka")
        got, st = minimum_spanning_forest(
            g, method="filter_boruvka",
            params=GHSParams(filter_sample_rate=rate,
                             filter_levels=levels))
        _assert_identical(got, want, g, (rate, levels))
        _assert_identical(got, plain, g, (rate, levels))
        assert 1 <= st.filter_passes <= MAX_PASSES

    inner()
