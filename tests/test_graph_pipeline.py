"""Device graph pipeline (DESIGN.md §7): byte-identity vs the numpy oracle,
partitioner forest-identity, determinism, and the packed-key cache."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import generators, kruskal_ref, pipeline
from repro.core.graph import Graph, pad_edges, pair_ids, preprocess
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams
from repro.core.partition import PARTITIONERS, build_edge_layout, \
    get_partitioner
from repro.core.pipeline import GraphSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _graphs_equal(a: Graph, b: Graph) -> bool:
    return (a.num_vertices == b.num_vertices
            and np.array_equal(a.src, b.src)
            and np.array_equal(a.dst, b.dst)
            and np.array_equal(a.weight.view(np.uint32),
                               b.weight.view(np.uint32)))


# ---------------------------------------------------------------------------
# Byte-identity: device pipeline vs numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", pipeline.KINDS)
@pytest.mark.parametrize("scale", [7, 9])
def test_device_build_byte_identical_to_host(kind, scale):
    spec = GraphSpec(kind, scale, seed=3)
    host = pipeline.build_host(spec)
    dev = pipeline.build(spec)
    assert dev.num_edges == host.num_edges
    assert _graphs_equal(host, dev.to_graph())
    host.validate()


def test_device_build_sharded_byte_identical():
    """1/2/4-shard device builds all reproduce the numpy oracle exactly
    (sample i never depends on its shard, and the dedup sort is global)."""
    out = _run_child(r"""
import json
import numpy as np
from repro.compat import make_mesh
from repro.core import pipeline
from repro.core.pipeline import GraphSpec

rows = []
for shards in (1, 2, 4):
    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    for kind in pipeline.KINDS:
        spec = GraphSpec(kind, 8, seed=5)
        h = pipeline.build_host(spec)
        d = pipeline.build(spec, mesh=mesh).to_graph()
        rows.append(dict(shards=shards, kind=kind, ok=bool(
            np.array_equal(h.src, d.src) and np.array_equal(h.dst, d.dst)
            and np.array_equal(h.weight.view(np.uint32),
                               d.weight.view(np.uint32)))))
print(json.dumps(rows))
""", devices=4)
    rows = json.loads(out.strip().splitlines()[-1])
    assert len(rows) == 3 * len(pipeline.KINDS)
    bad = [r for r in rows if not r["ok"]]
    assert not bad, bad


def test_device_edges_feed_engines():
    """DeviceEdges hand straight to both engines; forests match Kruskal on
    the byte-identical host mirror and the sync contract holds."""
    spec = GraphSpec("geo_knn", 9, seed=1)
    dev = pipeline.build(spec)
    want = kruskal_ref.kruskal(pipeline.build_host(spec))
    got_b, st = minimum_spanning_forest(dev, method="boruvka")
    assert np.array_equal(got_b.edge_mask, want.edge_mask)
    assert st.host_syncs == st.intervals + st.extra_syncs
    assert st.extra_syncs == 1               # the final state fetch
    got_g, _ = minimum_spanning_forest(dev, method="ghs")
    assert np.array_equal(got_g.edge_mask, want.edge_mask)


def test_prepare_edges_staging_signal():
    """prepare_edges records which path staged the input and WARNS when a
    DeviceEdges source silently misses the no-host-round-trip fast path
    (regression: the fallback used to be invisible)."""
    import warnings

    from repro.core import runtime

    spec = GraphSpec("rmat", 7, seed=2)
    dev = pipeline.build(spec)

    # Block layout, single shard: capacity % 1 == 0, fast path engages.
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # any warning here is a bug
        bundle = runtime.prepare_edges(dev, "block", None, chunk=8)
    assert bundle.staging == "device"

    # Non-block partitioner: host mirror, loudly.
    with pytest.warns(UserWarning, match="fast path"):
        bundle = runtime.prepare_edges(dev, "hashed", None, chunk=8)
    assert bundle.staging == "host"

    # Host Graph input: host staging is the contract, not a fallback.
    g = generators.generate("rmat", 6, seed=1)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        bundle = runtime.prepare_edges(g, "block", None, chunk=8)
    assert bundle.staging == "host"

    # The engine surfaces the taken path on its stats ledger.
    _, st = minimum_spanning_forest(dev, method="boruvka")
    assert st.edge_staging == "device"
    _, st = minimum_spanning_forest(g, method="boruvka")
    assert st.edge_staging == "host"


def test_prepare_edges_fast_path_every_shard_count():
    """The DeviceEdges fast path must engage for block layouts at every
    shard count the suite sweeps (pipeline capacities are pow2 multiples
    of the shard count, so capacity % num_shards == 0 by construction)."""
    out = _run_child(r"""
import json
import warnings
import numpy as np
from repro.compat import make_mesh
from repro.core import kruskal_ref, pipeline
from repro.core.mst_api import minimum_spanning_forest
from repro.core.pipeline import GraphSpec

rows = []
for shards in (1, 2, 4):
    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    spec = GraphSpec("rmat", 8, seed=5)
    dev = pipeline.build(spec, mesh=mesh)
    want = kruskal_ref.kruskal(pipeline.build_host(spec))
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # a fast-path miss warns -> fails
        res, st = minimum_spanning_forest(dev, method="boruvka", mesh=mesh)
    rows.append(dict(shards=shards, staging=st.edge_staging,
                     exact=bool(np.array_equal(res.edge_mask,
                                               want.edge_mask))))
print(json.dumps(rows))
""", devices=4)
    rows = json.loads(out.strip().splitlines()[-1])
    assert [r["shards"] for r in rows] == [1, 2, 4]
    for r in rows:
        assert r["staging"] == "device", r
        assert r["exact"], r


# ---------------------------------------------------------------------------
# Partitioners
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("part", sorted(PARTITIONERS))
def test_partitioners_bit_identical_both_engines(part):
    g = generators.generate("rmat", 8, seed=11)
    want = kruskal_ref.kruskal(g)
    for loop in ("device", "host"):
        got, _ = minimum_spanning_forest(
            g, method="boruvka",
            params=GHSParams(partitioner=part, round_loop=loop))
        assert np.array_equal(got.edge_mask, want.edge_mask), (part, loop)
    gg = generators.generate("rmat", 7, seed=3)
    wg = kruskal_ref.kruskal(gg)
    got, _ = minimum_spanning_forest(
        gg, method="ghs", params=GHSParams(partitioner=part))
    assert np.array_equal(got.edge_mask, wg.edge_mask), part


def test_partitioner_star_hub_balanced_vs_block():
    """The adversarial star keeps every edge on vertex 0; all partitioners
    must still elect the exact Kruskal forest."""
    spec = GraphSpec("star", 8, seed=0)
    g = pipeline.build_host(spec)
    want = kruskal_ref.kruskal(g)
    for part in sorted(PARTITIONERS):
        got, _ = minimum_spanning_forest(
            g, method="boruvka", params=GHSParams(partitioner=part))
        assert np.array_equal(got.edge_mask, want.edge_mask), part


def test_edge_layout_covers_every_edge_once():
    g = generators.generate("random", 8, seed=2)
    for name in sorted(PARTITIONERS):
        layout = build_edge_layout(g, get_partitioner(name), 4, chunk=32)
        eids = layout.eid[layout.eid >= 0]
        assert np.array_equal(np.sort(eids), np.arange(g.num_edges))
        assert layout.num_slots == 4 * layout.block


@pytest.mark.parametrize("n,shards", [(10, 4), (130, 4), (7, 3), (64, 4)])
def test_vertex_perm_is_block_capacity_respecting_permutation(n, shards):
    """Regression: every partitioner's vertex relabeling must be a true
    permutation of [0, n) whose blocks respect the engine's block rule —
    including when the shard count does not divide n (the last block is
    short; the balanced snake must not leak ids ≥ n)."""
    rng = np.random.default_rng(0)
    g = preprocess(rng.integers(0, n, 6 * n), rng.integers(0, n, 6 * n),
                   rng.random(6 * n, dtype=np.float32) * 0.9 + 0.05, n)
    block = -(-n // shards)
    for name in sorted(PARTITIONERS):
        perm = get_partitioner(name).vertex_perm(g, shards)
        assert np.array_equal(np.sort(perm), np.arange(n)), name
        counts = np.bincount(perm // block, minlength=shards)
        assert counts.max() <= block, name


def test_ghs_balanced_partitioner_non_pow2_vertices():
    """End-to-end regression for the same bug class: GHS + balanced
    partitioning on a graph whose vertex count no shard count divides."""
    rng = np.random.default_rng(7)
    n, m = 130, 700
    g = preprocess(rng.integers(0, n, m), rng.integers(0, n, m),
                   rng.random(m, dtype=np.float32) * 0.9 + 0.05, n)
    want = kruskal_ref.kruskal(g)
    for part in sorted(PARTITIONERS):
        got, _ = minimum_spanning_forest(
            g, method="ghs", params=GHSParams(partitioner=part))
        assert np.array_equal(got.edge_mask, want.edge_mask), part


def test_preprocess_general_path_matches_oracle():
    """The scale > 17 device-preprocess branch (pair-id sort + segmented
    scatter-min; the narrow-key fast path cannot pack those scales) must
    match graph.preprocess bit-for-bit.  The branch is selected by the
    ``scale`` argument alone, so it is exercised directly on small arrays."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from repro.core.pipeline import _preprocess_device

    rng = np.random.default_rng(3)
    cap, m, n = 512, 400, 1 << 18
    src = rng.integers(0, n, cap).astype(np.uint64)
    dst = rng.integers(0, n, cap).astype(np.uint64)
    dst[::7] = src[::7]                     # self-loops
    dst[1::5] = dst[::5][:len(dst[1::5])]   # extra collisions
    src[1::5] = src[::5][:len(src[1::5])]
    w = (rng.integers(0, 1 << 23, cap).astype(np.float32) + 0.5) * 2.0 ** -23
    with enable_x64():
        s, d, k, cnt = jax.jit(
            lambda s, d, w, c: _preprocess_device(
                s, d, w, c, num_samples=m, cap=cap, scale=18)
        )(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w),
          jnp.arange(cap, dtype=np.uint64))
    cnt = int(cnt)
    want = preprocess(src[:m], dst[:m], w[:m], n)
    assert cnt == want.num_edges
    assert np.array_equal(np.asarray(s)[:cnt], want.src)
    assert np.array_equal(np.asarray(d)[:cnt], want.dst)
    assert np.array_equal(np.asarray(k)[:cnt], want.packed_keys)


def test_unknown_partitioner_raises():
    g = generators.generate("random", 6, seed=2)
    with pytest.raises(ValueError, match="unknown partitioner"):
        minimum_spanning_forest(
            g, method="boruvka", params=GHSParams(partitioner="nope"))


# ---------------------------------------------------------------------------
# Determinism + satellite invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(generators.GENERATORS))
def test_generator_determinism(kind):
    """Same kind/scale/seed ⇒ identical graphs, run to run."""
    a = generators.generate(kind, 7, seed=13)
    b = generators.generate(kind, 7, seed=13)
    assert _graphs_equal(a, b)
    c = generators.generate(kind, 7, seed=14)
    assert not _graphs_equal(a, c)          # the seed actually matters


@pytest.mark.parametrize("kind", pipeline.KINDS)
def test_device_pipeline_determinism(kind):
    spec = GraphSpec(kind, 7, seed=13)
    assert _graphs_equal(pipeline.build(spec).to_graph(),
                         pipeline.build(spec).to_graph())


def test_packed_keys_cached_across_pads():
    g = generators.generate("random", 7, seed=5)
    first = g.packed_keys
    pad_edges(g, 64)
    pad_edges(g, 128)
    assert g.packed_keys is first          # one array, reused every pad


def test_pair_ids_checks_packing_precondition():
    u = np.array([1], dtype=np.int64)
    with pytest.raises(AssertionError, match="32-bit"):
        pair_ids(u, u + 1, 2 ** 32 + 1)


def test_preprocess_keeps_min_weight_copy():
    """Duplicate (u, v) samples collapse to the min-weight copy (§3.1)."""
    src = np.array([3, 1, 1, 3, 5, 1])
    dst = np.array([1, 3, 3, 1, 5, 3])     # (1,3) ×4 both directions; 5-loop
    w = np.array([0.5, 0.25, 0.75, 0.125, 0.9, 0.25], np.float32)
    g = preprocess(src, dst, w, 8)
    assert g.num_edges == 1
    assert (int(g.src[0]), int(g.dst[0])) == (1, 3)
    assert float(g.weight[0]) == 0.125


def _run_child(code: str, devices: int = 4) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout
