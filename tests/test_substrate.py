"""Checkpoint, data pipeline, optimizer, sharding-spec unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.data.tokens import DataConfig, make_dataset
from repro.sharding.specs import ShardingRules, param_spec
from repro.train import optimizer as opt_lib


def test_checkpoint_roundtrip(tmp_path):
    tree = dict(a=np.arange(12, dtype=np.float32).reshape(3, 4),
                b=dict(c=np.ones(5, np.int32), d=np.float32(2.5)))
    ckpt_lib.save(str(tmp_path), 7, tree)
    restored, meta = ckpt_lib.restore(str(tmp_path), tree)
    assert meta["step"] == 7
    assert np.array_equal(restored["a"], tree["a"])
    assert np.array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_atomic_and_prune(tmp_path):
    tree = dict(x=np.zeros(3, np.float32))
    for s in (1, 2, 3, 4, 5):
        ckpt_lib.save(str(tmp_path), s, tree, keep_last=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]
    assert ckpt_lib.latest_step(str(tmp_path)) == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt_lib.save(str(tmp_path), 1, dict(x=np.zeros(3, np.float32)))
    with pytest.raises(ValueError):
        ckpt_lib.restore(str(tmp_path), dict(x=np.zeros(4, np.float32)))


def test_data_deterministic_resume():
    ds = make_dataset(DataConfig(vocab=100, seed=3), batch=4, seq=16)
    b5 = ds.batch_at(5)
    b5_again = ds.batch_at(5)
    assert np.array_equal(b5["tokens"], b5_again["tokens"])
    assert np.array_equal(b5["tokens"][:, 1:], b5["labels"][:, :-1])


def test_token_file_dataset(tmp_path):
    path = tmp_path / "toks.bin"
    np.arange(10000, dtype=np.uint16).tofile(path)
    ds = make_dataset(DataConfig(kind="file", path=str(path), vocab=65536),
                      batch=2, seq=16)
    b0 = ds.batch_at(0)
    assert b0["tokens"].shape == (2, 16)
    assert np.array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


def test_adamw_decreases_loss():
    rng = np.random.default_rng(0)
    w_true = rng.standard_normal(8).astype(np.float32)
    x = rng.standard_normal((64, 8)).astype(np.float32)
    y = x @ w_true
    params = dict(w=jnp.zeros(8))
    state = opt_lib.init(params)
    cfg = opt_lib.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)

    def loss_fn(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    l0 = float(loss_fn(params))
    for _ in range(50):
        g = jax.grad(loss_fn)(params)
        params, state, _ = opt_lib.update(g, state, params, cfg)
    assert float(loss_fn(params)) < l0 * 0.1


def test_param_spec_rules():
    rules = ShardingRules()
    assert param_spec("layers/attn/wq", (24, 512, 512), rules)[-1] == "model"
    assert param_spec("embed", (1000, 64), rules)[0] == "model"
    assert param_spec("layers/ln1", (24, 64), rules) == \
        jax.sharding.PartitionSpec()


def test_grad_compression_error_feedback():
    from repro.sharding.collectives import compress_tree
    g = dict(w=jnp.asarray(np.random.default_rng(0)
                           .standard_normal(1000), jnp.float32))
    comp, res = compress_tree(g, None)
    assert comp["w"].dtype == jnp.bfloat16
    # error feedback: compressed + residual reconstructs the original
    rec = comp["w"].astype(jnp.float32) + res["w"]
    assert float(jnp.abs(rec - g["w"]).max()) < 1e-6
