"""Batched multi-graph engine (DESIGN.md §8): every lane oracle-exact and
bit-identical to its single-graph solve, bucketing never mixes shapes, and
the negative paths (over-capacity packs, unknown knobs) reject loudly."""
import numpy as np
import pytest

from repro.core import generators, kruskal_ref, pipeline, runtime
from repro.core.graph import PAD_VERTEX, preprocess
from repro.core.keys import INF_KEY
from repro.core.mst_api import minimum_spanning_forest, \
    minimum_spanning_forests
from repro.core.params import GHSParams


def _single_edge(n=2, w=0.5):
    return preprocess(np.array([0]), np.array([1]),
                      np.array([w], np.float32), n)


def _edgeless(n=6):
    return preprocess(np.zeros(0), np.zeros(0), np.zeros(0, np.float32), n)


def _singleton():
    """One vertex, zero edges — the smallest legal graph."""
    return preprocess(np.zeros(0), np.zeros(0), np.zeros(0, np.float32), 1)


def _mixed_batch():
    """Mixed kinds, scales, AND degenerate shapes — several buckets."""
    return [
        generators.generate("rmat", 7, seed=1),
        generators.generate("random", 8, seed=2),
        generators.generate("rmat", 7, seed=3),       # same bucket as [0]
        generators.generate("disconnected", 6, seed=4),
        _edgeless(),
        _single_edge(),
        generators.generate("rmat", 6, seed=5),
    ]


# ---------------------------------------------------------------------------
# Tentpole acceptance: oracle-exact + bit-identical to single-graph solves
# ---------------------------------------------------------------------------

def test_batched_oracle_exact_and_bit_identical_to_single():
    graphs = _mixed_batch()
    results, stats = minimum_spanning_forests(graphs)
    assert len(results) == len(graphs)
    assert len(stats.rounds_per_graph) == len(graphs)
    for i, (g, got) in enumerate(zip(graphs, results)):
        want = kruskal_ref.kruskal(g)
        single, st_single = minimum_spanning_forest(g, method="boruvka")
        assert np.array_equal(got.edge_mask, want.edge_mask), i
        assert np.array_equal(got.edge_mask, single.edge_mask), i
        assert got.total_weight == single.total_weight, i
        assert got.num_components == want.num_components, i
        # the lane ran exactly the rounds the single-graph engine ran
        assert stats.rounds_per_graph[i] == st_single.rounds, i


def test_batched_sync_contract():
    """One readback per interval + ONE final fetch per bucket — host syncs
    must not scale with the number of graphs in a bucket."""
    graphs = _mixed_batch()
    _, stats = minimum_spanning_forests(graphs)
    assert stats.buckets >= 2                  # mixed shapes → real buckets
    assert stats.intervals >= stats.buckets
    assert stats.host_syncs == stats.intervals + stats.buckets
    # The batched driver's extra syncs ARE the per-bucket final fetches.
    assert stats.extra_syncs == stats.buckets


@pytest.mark.parametrize("bucket", ["pow2", "exact"])
def test_degenerate_shapes_solve_under_both_policies(bucket):
    """Zero-edge graphs land in cap=1 buckets under ``"exact"`` but cap=8
    under ``"pow2"`` — BOTH lanes must solve and unpack correctly, alone
    and mixed into multi-graph batches (empty, single-edge, and
    singleton-vertex graphs ride real traffic)."""
    # The policy split this test pins down:
    assert pipeline.bucket_shape(6, 0, bucket="pow2") == (8, 8)
    assert pipeline.bucket_shape(6, 0, bucket="exact") == (6, 1)
    assert pipeline.bucket_shape(1, 0, bucket="exact") == (1, 1)

    degenerates = [_edgeless(), _singleton(), _single_edge(), _edgeless(3)]
    mixed = degenerates + [generators.generate("rmat", 6, seed=5),
                           generators.generate("rmat", 7, seed=1),
                           _edgeless(5)]
    params = GHSParams(batch_bucket=bucket)
    for graphs in (degenerates, mixed):
        results, stats = minimum_spanning_forests(graphs, params=params)
        assert stats.host_syncs == stats.intervals + stats.extra_syncs
        for i, (g, res) in enumerate(zip(graphs, results)):
            want = kruskal_ref.kruskal(g)
            assert np.array_equal(res.edge_mask, want.edge_mask), (bucket, i)
            assert res.num_components == want.num_components, (bucket, i)
            single, _ = minimum_spanning_forest(g)
            assert np.array_equal(res.edge_mask, single.edge_mask), \
                (bucket, i)


def test_batched_compaction_bit_identical():
    """Per-lane pow2 compaction every interval leaves every forest
    bit-identical (the batched analogue of the single-graph contract)."""
    graphs = [generators.generate("rmat", 8, seed=s) for s in (1, 2, 3)]
    plain, st_p = minimum_spanning_forests(
        graphs, params=GHSParams(compaction="none"))
    compacted, st_c = minimum_spanning_forests(
        graphs, params=GHSParams(compaction="pow2", batch_check_frequency=1))
    assert st_c.compactions >= 1, "compaction path was not exercised"
    for a, b, g in zip(plain, compacted, graphs):
        want = kruskal_ref.kruskal(g)
        assert np.array_equal(a.edge_mask, want.edge_mask)
        assert np.array_equal(b.edge_mask, want.edge_mask)


def test_batched_host_loop_fallback_matches_device():
    graphs = _mixed_batch()
    dev, st_d = minimum_spanning_forests(
        graphs, params=GHSParams(round_loop="device"))
    host, st_h = minimum_spanning_forests(
        graphs, params=GHSParams(round_loop="host"))
    for a, b in zip(dev, host):
        assert np.array_equal(a.edge_mask, b.edge_mask)
        assert a.total_weight == b.total_weight
    assert st_d.rounds_per_graph == st_h.rounds_per_graph


def test_batched_device_edges_input():
    """DeviceEdges from the pipeline are accepted (host-mirrored for
    packing) and solve bit-identically."""
    spec = pipeline.GraphSpec("geo_knn", 7, seed=1)
    dev = pipeline.build(spec)
    host = pipeline.build_host(spec)
    want = kruskal_ref.kruskal(host)
    results, _ = minimum_spanning_forests([dev, host])
    assert np.array_equal(results[0].edge_mask, want.edge_mask)
    assert np.array_equal(results[1].edge_mask, want.edge_mask)


def test_batched_fallback_without_contraction_packing():
    """Buckets whose (fragment, weight, id) packing cannot fit one uint64
    — weights outside (0, 2), or 2·log2(n_pad) + 30 + log2(cap) > 64 —
    fall back to the plain vmapped round + compaction and must stay
    bit-identical to single solves and the oracle."""
    from repro.core.boruvka_dist import _contract_gate
    rng = np.random.default_rng(5)
    src = rng.integers(0, 64, 400)
    dst = rng.integers(0, 64, 400)
    w_wide = (rng.random(400, dtype=np.float32) * 3 + 0.5).astype(np.float32)
    g_wide = preprocess(src, dst, w_wide, 64)          # weights ≥ 2.0
    n = 1 << 12
    src = rng.integers(0, n, 1600)
    dst = rng.integers(0, n, 1600)
    w_big = rng.random(1600, dtype=np.float32) * 0.9 + 0.05
    g_big = preprocess(src, dst, w_big, n)             # 2s + 30 + c = 65
    for g in (g_wide, g_big):
        (batch,) = pipeline.pack_batch([g])
        assert _contract_gate(batch) is None
        want = kruskal_ref.kruskal(g)
        single, st_single = minimum_spanning_forest(g, method="boruvka")
        (got,), stats = minimum_spanning_forests([g])
        assert np.array_equal(got.edge_mask, want.edge_mask)
        assert np.array_equal(got.edge_mask, single.edge_mask)
        assert stats.rounds_per_graph == (st_single.rounds,)


def test_batched_empty_input():
    results, stats = minimum_spanning_forests([])
    assert results == []
    assert stats.buckets == 0 and stats.host_syncs == 0


# ---------------------------------------------------------------------------
# Bucketing: shapes never mix, padding invariants hold
# ---------------------------------------------------------------------------

def test_bucketing_never_mixes_shapes():
    graphs = _mixed_batch()
    batches = pipeline.pack_batch(graphs)
    seen = sorted(i for b in batches for i in b.indices)
    assert seen == list(range(len(graphs)))    # a partition of the input
    from repro.core.partition import pow2ceil
    for b in batches:
        for r, g in enumerate(b.graphs):
            assert graphs[b.indices[r]] is g
            # every lane's padded shape IS the bucket shape
            assert pow2ceil(max(g.num_vertices, 1)) == b.n_pad
            assert pow2ceil(max(g.num_edges, 8)) == b.cap
        assert b.src.shape == b.dst.shape == b.key.shape == \
            b.slot.shape == (b.batch_size, b.cap)
    shapes = [(b.n_pad, b.cap) for b in batches]
    assert len(set(shapes)) == len(shapes)     # one bucket per shape


def test_pack_batch_padding_invariants():
    graphs = [_single_edge(), generators.generate("rmat", 6, seed=7)]
    for b in pipeline.pack_batch(graphs):
        for r, g in enumerate(b.graphs):
            m = g.num_edges
            assert np.array_equal(b.src[r, :m], g.src)
            assert np.array_equal(b.dst[r, :m], g.dst)
            assert np.array_equal(b.key[r, :m], g.packed_keys)
            # padding tail: inert sentinels, never electable
            assert np.all(b.src[r, m:] == PAD_VERTEX)
            assert np.all(b.dst[r, m:] == PAD_VERTEX)
            assert np.all(b.key[r, m:] == INF_KEY)
            assert np.array_equal(b.slot[r], np.arange(b.cap))


def test_pack_batch_exact_policy_groups_identical_shapes_only():
    graphs = [generators.generate("rmat", 7, seed=1),
              generators.generate("rmat", 7, seed=2),
              generators.generate("rmat", 6, seed=3)]
    batches = pipeline.pack_batch(graphs, bucket="exact")
    # rmat graphs with different seeds dedup to different edge counts →
    # exact bucketing may not merge them; shapes must match exactly inside
    for b in batches:
        for g in b.graphs:
            assert g.num_vertices == b.n_pad
            assert g.num_edges == b.cap
    res, _ = minimum_spanning_forests(
        graphs, params=GHSParams(batch_bucket="exact"))
    for g, got in zip(graphs, res):
        assert np.array_equal(got.edge_mask,
                              kruskal_ref.kruskal(g).edge_mask)


# ---------------------------------------------------------------------------
# Negative paths
# ---------------------------------------------------------------------------

def test_pack_batch_rejects_over_capacity_graphs():
    big = generators.generate("rmat", 8, seed=1)
    small = _single_edge()
    with pytest.raises(ValueError, match="exceeds pack_batch capacity"):
        pipeline.pack_batch([small, big], max_edges=64)
    with pytest.raises(ValueError,
                       match=r"graph 1 .*num_vertices=256 > max_vertices=64"):
        pipeline.pack_batch([small, big], max_vertices=64)
    # end to end through the params knobs — on BOTH loop drivers (the host
    # fallback must not bypass the serving-path capacity guard)
    for loop in ("device", "host"):
        with pytest.raises(ValueError, match="exceeds pack_batch capacity"):
            minimum_spanning_forests(
                [big],
                params=GHSParams(batch_max_edges=8, round_loop=loop))


def test_pack_batch_rejects_unknown_bucket_policy():
    with pytest.raises(ValueError, match="unknown batch bucket policy"):
        pipeline.pack_batch([_single_edge()], bucket="golf")
    with pytest.raises(ValueError, match="unknown batch bucket policy"):
        minimum_spanning_forests(
            [_single_edge()], params=GHSParams(batch_bucket="golf"))


def test_resolve_round_loop_rejects_unknown_modes():
    with pytest.raises(ValueError, match="unknown round_loop"):
        runtime.resolve_round_loop("warp")
    g = _single_edge()
    # both the single-graph and the batched entry validate the knob
    with pytest.raises(ValueError, match="unknown round_loop"):
        minimum_spanning_forest(
            g, method="boruvka", params=GHSParams(round_loop="warp"))
    with pytest.raises(ValueError, match="unknown round_loop"):
        minimum_spanning_forests([g], params=GHSParams(round_loop="warp"))


def test_batched_ghs_method_rejected():
    with pytest.raises(ValueError, match="method='boruvka'"):
        minimum_spanning_forests([_single_edge()], method="ghs")


def test_batched_inf_sentinel_weights_rejected():
    bad = preprocess(
        np.array([0]), np.array([1]),
        np.array([np.uint32(0xFFFFFFFF)]).view(np.float32), 2)
    with pytest.raises(ValueError, match="INF sentinel"):
        minimum_spanning_forests([bad])
