"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real device
count (1); only launch/dryrun.py forces 512 host devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
