"""Continuous-batching MST service (DESIGN.md §12): deterministic dispatch
under a fake clock (no sleeps in any assertion), typed backpressure sheds,
arrival-order completion, and every served forest oracle-exact."""
import dataclasses

import numpy as np
import pytest

from repro.core import generators, incremental, kruskal_ref, mst_api, pipeline
from repro.core.graph import preprocess
from repro.core.params import GHSParams
from repro.launch.serve import (LATENCY_WINDOW, MSTService, OversizeError,
                                QueueFullError, ServeStats, run_poisson)


class FakeClock:
    """Injectable time source: tests advance it explicitly instead of
    sleeping, so deadline expiry is exact and assertions never race."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _g(seed, scale=4, degree=4):
    return generators.generate("rmat", scale, avg_degree=degree, seed=seed)


# Seeds whose scale-4 rmat graphs all share the (n_pad=16, cap=32) pow2
# bucket — the same-bucket tests draw from this pool.
_POOL = (0, 2, 3, 4, 5, 6, 9, 10, 12, 13)


def _same_bucket(k):
    return [_g(s) for s in _POOL[:k]]


def _params(**kw):
    base = dict(serve_lanes=3, serve_max_wait_ms=50.0, serve_max_queue=6,
                batch_max_vertices=64, batch_max_edges=256)
    base.update(kw)
    return GHSParams(**base)


def _assert_oracle(graph, result):
    oracle = kruskal_ref.kruskal(graph)
    assert np.array_equal(result.edge_mask, oracle.edge_mask)
    assert result.num_components == oracle.num_components


# ---------------------------------------------------------------------------
# Dispatch triggers
# ---------------------------------------------------------------------------

def test_size_flush_fires_without_time_passing():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    graphs = _same_bucket(3)                 # one bucket: full at 3 lanes
    futs = [svc.submit(g) for g in graphs]
    assert not any(f.done() for f in futs)   # submit never dispatches
    assert svc.poll(now=0.0) == 1
    assert svc.stats.size_flushes == 1
    assert svc.stats.deadline_flushes == 0
    assert svc.stats.ghost_lanes == 0
    for g, f in zip(graphs, futs):
        assert f.done()
        _assert_oracle(g, f.result())


def test_deadline_flush_dispatches_at_occupied_width():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    fut = svc.submit(_g(7))
    # Under the deadline: nothing moves, however often we poll.
    assert svc.poll(now=0.049) == 0
    assert not fut.done()
    # At the deadline: the solo flush dispatches at width 1 — no ghost
    # lanes (the adaptive policy; the fixed-width one padded to 3 and
    # drove the low-rate mean to ~21x p50, BENCH_serving history).
    assert svc.poll(now=0.050) == 1
    assert svc.stats.deadline_flushes == 1
    assert svc.stats.size_flushes == 0
    assert svc.stats.ghost_lanes == 0
    assert fut.done()
    _assert_oracle(_g(7), fut.result())


def test_deadline_measured_from_oldest_request():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    svc.submit(_g(_POOL[0]))                 # t = 0
    clock.advance(0.04)
    svc.submit(_g(_POOL[1]))                 # t = 0.04, same bucket
    # 10 ms later the OLDEST is 50 ms old: both flush together at the
    # exact pow2 width 2 — again no ghosts.
    assert svc.poll(now=0.050) == 1
    assert svc.stats.deadline_flushes == 1
    assert svc.stats.completed == 2
    assert svc.stats.ghost_lanes == 0


def test_partial_flush_rounds_to_pow2_width():
    # 5 occupied lanes under serve_lanes=8 → pow2ceil(5)=8... use 3-of-4:
    # serve_lanes=4, 3 requests → width 4, one ghost.
    clock = FakeClock()
    svc = MSTService(_params(serve_lanes=4, serve_max_queue=8),
                     clock=clock)
    futs = [svc.submit(g) for g in _same_bucket(3)]
    assert svc.poll(now=0.050) == 1
    assert svc.stats.ghost_lanes == 1        # padded to pow2ceil(3) = 4
    for g, f in zip(_same_bucket(3), futs):
        _assert_oracle(g, f.result())


def test_bit_identical_to_single_graph_solve():
    svc = MSTService(_params(), clock=FakeClock())
    graphs = _same_bucket(3)
    futs = [svc.submit(g) for g in graphs]
    svc.poll(now=0.0)
    for g, f in zip(graphs, futs):
        single, _ = mst_api.minimum_spanning_forest(g)
        assert np.array_equal(f.result().edge_mask, single.edge_mask)


def test_completion_in_arrival_order():
    svc = MSTService(_params(), clock=FakeClock())
    order = []
    for i, g in enumerate(_same_bucket(3)):
        fut = svc.submit(g)
        fut.add_done_callback(lambda f, i=i: order.append(i))
    svc.poll(now=0.0)
    assert order == [0, 1, 2]


def test_mixed_buckets_route_and_drain():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    graphs = [_g(1, scale=3), _g(2, scale=5), _g(3, scale=3),
              preprocess(np.zeros(0), np.zeros(0),
                         np.zeros(0, np.float32), 6)]
    futs = [svc.submit(g) for g in graphs]
    assert len(svc._queues) >= 2             # distinct shapes, own queues
    assert svc.poll(now=0.0) == 0            # none full, none expired
    assert svc.drain() == len(svc._queues)
    assert svc.stats.drain_flushes == len(svc._queues)
    for g, f in zip(graphs, futs):
        _assert_oracle(g, f.result())


# ---------------------------------------------------------------------------
# Backpressure
# ---------------------------------------------------------------------------

def test_oversize_shed_is_typed_and_counted():
    svc = MSTService(_params(batch_max_edges=8), clock=FakeClock())
    with pytest.raises(OversizeError, match="exceeds pack_batch capacity"):
        svc.submit(_g(3, scale=5, degree=8))
    assert svc.stats.shed_oversize == 1
    assert svc.stats.accepted == 0
    assert svc.queue_depth() == 0            # shed requests never queue


def test_queue_full_shed_then_poll_recovers():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    futs = [svc.submit(g) for g in _same_bucket(6)]   # serve_max_queue
    with pytest.raises(QueueFullError, match="queue is full"):
        svc.submit(_g(_POOL[6]))
    assert svc.stats.shed_queue_full == 1
    assert svc.stats.max_queue_depth == 6
    # One poll drains the backlog as two size flushes; admission reopens.
    assert svc.poll(now=0.0) == 2
    assert svc.stats.size_flushes == 2
    assert all(f.done() for f in futs)
    svc.submit(_g(_POOL[7]))
    assert svc.stats.accepted == 7


def test_shed_rate_accounting():
    svc = MSTService(_params(batch_max_edges=8), clock=FakeClock())
    svc.submit(preprocess(np.array([0]), np.array([1]),
                          np.array([0.5], np.float32), 2))
    with pytest.raises(OversizeError):
        svc.submit(_g(3, scale=5, degree=8))
    assert svc.stats.shed == 1
    assert svc.stats.shed_rate == pytest.approx(0.5)


def test_service_rejects_inconsistent_knobs():
    with pytest.raises(ValueError, match="serve_lanes"):
        MSTService(_params(serve_lanes=0))
    with pytest.raises(ValueError, match="serve_max_queue"):
        MSTService(_params(serve_lanes=4, serve_max_queue=2))


# ---------------------------------------------------------------------------
# Warmup lattice
# ---------------------------------------------------------------------------

def test_warmup_covers_the_pow2_lattice():
    p = _params(batch_max_vertices=8, batch_max_edges=16)
    svc = MSTService(p, clock=FakeClock())
    # n_pad in {1, 2, 4, 8} x cap in {8, 16} = 8 shapes, each warmed at
    # every adaptive flush width {1, 2, 3} (serve_lanes=3).
    assert svc.flush_widths() == [1, 2, 3]
    assert svc.warmup() == 24
    assert svc.stats.buckets_warmed == 24
    # Warmup solves ghosts only: no request counters move.
    assert svc.stats.accepted == svc.stats.completed == 0
    assert svc.stats.flushes == 0


def test_serve_dispatch_runs_to_completion():
    # A flush's solve must converge inside ONE dispatch (one readback, no
    # mid-solve compaction): the shrink ladder's recompiles can then never
    # land inside a request's latency, and warmup needs exactly one
    # executable per (shape, width).
    svc = MSTService(_params(), clock=FakeClock())
    dp = svc._dispatch_params(16)
    assert dp.batch_check_frequency >= 16 + 2
    graphs = _same_bucket(3)
    batch = pipeline.pack_bucket(graphs, 16, 32)
    results, st = mst_api.solve_packed(batch, params=dp)
    assert st.intervals == 1
    assert st.compactions == 0
    for g, res in zip(graphs, results):
        _assert_oracle(g, res)


def test_interval_fn_cache_holds_a_serving_lattice():
    # Warmup's value lives inside the per-contract_bits jit objects:
    # evicting one from the builder cache destroys every executable
    # compiled through it, re-paying those compiles mid-request.  A
    # 256-vertex/1024-edge lattice has ~60 distinct (s_bits, c_bits)
    # combos; pin that the builder cache retains a full lattice's worth
    # (regression: maxsize=16 silently discarded most of the warmup —
    # first-encounter flushes then stalled for seconds under load).
    from repro.core.boruvka_dist import _build_batch_interval_fn
    combos = [(s, c) for s in range(1, 9) for c in range(3, 11)]
    fns = [_build_batch_interval_fn(False, bits) for bits in combos]
    for bits, fn in zip(combos, fns):
        assert _build_batch_interval_fn(False, bits) is fn


def test_warmup_skips_unbounded_and_exact_policies():
    assert MSTService(_params(batch_max_vertices=0, batch_max_edges=0),
                      clock=FakeClock()).warmup() == 0
    assert MSTService(
        _params(batch_bucket="exact"), clock=FakeClock()).warmup() == 0


# ---------------------------------------------------------------------------
# Poisson driver in virtual time
# ---------------------------------------------------------------------------

def test_run_poisson_virtual_time_deterministic():
    clock = FakeClock()
    svc = MSTService(_params(serve_max_queue=32), clock=clock)
    graphs = [_g(s, scale=3) for s in range(8)]
    futs = run_poisson(svc, graphs, rate=200.0, seed=1,
                       sleep=clock.advance)
    assert len(futs) == 8
    served = [f for f in futs if f is not None]
    assert len(served) == 8 - svc.stats.shed
    assert all(f.done() for f in served)
    assert svc.stats.completed == len(served)
    assert len(svc.stats.latencies_ms) == len(served)
    assert svc.stats.graphs_per_s > 0
    for g, f in zip(graphs, futs):
        if f is not None:
            _assert_oracle(g, f.result())


# ---------------------------------------------------------------------------
# Latency ledger: virtual timebase + bounded window
# ---------------------------------------------------------------------------

def test_virtual_clock_latency_single_timebase():
    # Regression: _flush used to stamp completion with self._clock() even
    # when poll(now=...) drove the dispatcher in virtual time, mixing
    # timebases (a FakeClock pinned at 0 recorded ~0ms for a 50ms wait).
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    svc.submit(_g(7))                        # t_submit = clock() = 0.0
    assert svc.poll(now=0.050) == 1
    assert svc.stats.latencies_ms[-1] == pytest.approx(50.0)
    # drain(now=...) threads the same stamp.
    svc.submit(_g(8), t_arrival=0.050)
    assert svc.drain(now=0.075) == 1
    assert svc.stats.latencies_ms[-1] == pytest.approx(25.0)


def test_real_clock_latency_includes_solve_time():
    # Without an injected now, completion is stamped AFTER the solve from
    # the service clock — a fake clock advanced between submit and poll
    # shows the elapsed time; it is never stamped from poll entry.
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    svc.submit(_g(7))
    clock.advance(0.2)
    assert svc.poll() == 1                   # deadline long expired
    assert svc.stats.latencies_ms[-1] == pytest.approx(200.0)


def test_latency_window_soak_stays_memory_flat():
    # A million-request soak must not grow the ledger without bound.
    stats = ServeStats()
    for i in range(1_000_000):
        stats.record_latency(float(i % 97))
        stats.completed += 1
    assert len(stats.latencies_ms) == LATENCY_WINDOW
    s = stats.summary()
    assert s["completed"] == 1_000_000       # exact count survives
    assert s["latency_samples"] == LATENCY_WINDOW
    # Percentiles are over the trailing window and stay finite.
    assert 0.0 <= stats.percentile(50) <= 96.0
    assert s["mean_ms"] == pytest.approx(
        float(np.mean(np.asarray(stats.latencies_ms))), abs=1e-3)


# ---------------------------------------------------------------------------
# Update-request kind (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _batch_for(state, rng):
    n = state.graph.num_vertices
    ins = [(int(rng.integers(n)), int(rng.integers(n)),
            float(rng.uniform(0.01, 0.99))) for _ in range(4)]
    tree = np.flatnonzero(state.forest.edge_mask)
    dele = [(int(state.graph.src[i]), int(state.graph.dst[i]))
            for i in rng.choice(tree, min(2, tree.size), replace=False)]
    return incremental.EdgeBatch.make(ins, dele)


def test_update_requests_share_flush_and_match_apply_updates():
    rng = np.random.default_rng(3)
    clock = FakeClock()
    svc = MSTService(_params(serve_max_queue=8), clock=clock)
    states = [mst_api.incremental_forest(_g(s))[0] for s in _POOL[:3]]
    batches = [_batch_for(st, rng) for st in states]
    futs = [svc.submit_update(st, b) for st, b in zip(states, batches)]
    assert not any(f.done() for f in futs)   # submit never dispatches
    assert svc.stats.update_requests == 3
    assert svc.poll(now=0.0) == 1            # full at serve_lanes=3
    assert svc.stats.size_flushes == 1
    for st, b, f in zip(states, batches, futs):
        got = f.result()
        want, _ = mst_api.apply_updates(st, b)
        assert np.array_equal(got.forest.edge_mask, want.forest.edge_mask)
        assert got.forest.total_weight == want.forest.total_weight
    assert svc.stats.updates_applied > 0
    assert svc.stats.completed == 3


def test_update_and_solve_buckets_coexist():
    rng = np.random.default_rng(4)
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    g = _g(11)
    state, _ = mst_api.incremental_forest(_g(12))
    batch = _batch_for(state, rng)
    f_solve = svc.submit(g)
    f_upd = svc.submit_update(state, batch)
    assert len(svc._queues) == 2             # distinct kinds, own queues
    assert svc.drain(now=0.0) == 2
    _assert_oracle(g, f_solve.result())
    want, _ = mst_api.apply_updates(state, batch)
    assert np.array_equal(f_upd.result().forest.edge_mask,
                          want.forest.edge_mask)


def test_update_oversize_shed_is_typed():
    # Base graph fits the cap; the insert batch pushes it over.
    base = preprocess(np.arange(4), np.arange(4) + 1,
                      np.full(4, 0.5, np.float32), 16)
    state, _ = mst_api.incremental_forest(base)
    svc = MSTService(_params(batch_max_edges=8), clock=FakeClock())
    big = incremental.EdgeBatch.make(
        [(i, (i + 7) % 16, 0.5 + i * 1e-3) for i in range(16)
         if i != (i + 7) % 16], [])
    with pytest.raises(OversizeError, match="exceeds pack_batch capacity"):
        svc.submit_update(state, big)
    assert svc.stats.shed_oversize == 1
    assert svc.stats.accepted == 0


def test_update_bad_batch_raises_value_error_not_shed():
    state, _ = mst_api.incremental_forest(_g(5))
    svc = MSTService(_params(), clock=FakeClock())
    bad = incremental.EdgeBatch.make([(0, 10**6, 0.5)], [])
    with pytest.raises(ValueError, match="endpoints"):
        svc.submit_update(state, bad)
    assert svc.stats.shed == 0               # input bug, not backpressure


# ---------------------------------------------------------------------------
# Incremental admission primitives (pipeline.bucket_shape / pack_bucket)
# ---------------------------------------------------------------------------

def test_bucket_shape_matches_pack_batch_routing():
    graphs = [_g(1, scale=3), _g(2, scale=5), _g(3, scale=3)]
    for bucket in ("pow2", "exact"):
        batches = pipeline.pack_batch(graphs, bucket=bucket)
        routed = {}
        for i, g in enumerate(graphs):
            shape = pipeline.bucket_shape(g.num_vertices, g.num_edges,
                                          bucket=bucket)
            routed.setdefault(shape, []).append(i)
        assert routed == {(b.n_pad, b.cap): list(b.indices)
                          for b in batches}


def test_bucket_shape_raises_like_pack_batch():
    with pytest.raises(ValueError, match="unknown batch bucket policy"):
        pipeline.bucket_shape(4, 4, bucket="golf")
    with pytest.raises(ValueError, match="num_vertices=100 > max_vertices"):
        pipeline.bucket_shape(100, 4, max_vertices=64)
    with pytest.raises(ValueError, match="num_edges=500 > max_edges"):
        pipeline.bucket_shape(8, 500, max_edges=256)


def test_pack_bucket_validates_fit_and_indices():
    g = _g(5, scale=3)
    with pytest.raises(ValueError, match="does not fit bucket"):
        pipeline.pack_bucket([g], 2, 4)
    with pytest.raises(ValueError, match="indices length"):
        pipeline.pack_bucket([g], 8, 256, indices=(0, 1))
    with pytest.raises(ValueError, match="at least one graph"):
        pipeline.pack_bucket([], 8, 8)


def test_solve_packed_equals_batched_entry():
    graphs = [_g(s, scale=4) for s in range(4)]
    n_pad, cap = pipeline.bucket_shape(
        max(g.num_vertices for g in graphs),
        max(g.num_edges for g in graphs))
    batch = pipeline.pack_bucket(graphs, n_pad, cap)
    results, stats = mst_api.solve_packed(batch)
    ref, _ = mst_api.minimum_spanning_forests(graphs)
    for got, want in zip(results, ref):
        assert np.array_equal(got.edge_mask, want.edge_mask)
    assert stats.host_syncs == stats.intervals + stats.extra_syncs


def test_solve_packed_rejects_host_loop():
    g = _g(6, scale=3)
    batch = pipeline.pack_bucket([g], 8, 64)
    with pytest.raises(ValueError, match="round_loop='device'"):
        mst_api.solve_packed(
            batch, params=dataclasses.replace(GHSParams(),
                                              round_loop="host"))
