"""Continuous-batching MST service (DESIGN.md §12): deterministic dispatch
under a fake clock (no sleeps in any assertion), typed backpressure sheds,
arrival-order completion, and every served forest oracle-exact."""
import dataclasses

import numpy as np
import pytest

from repro.core import generators, kruskal_ref, mst_api, pipeline
from repro.core.graph import preprocess
from repro.core.params import GHSParams
from repro.launch.serve import (MSTService, OversizeError, QueueFullError,
                                run_poisson)


class FakeClock:
    """Injectable time source: tests advance it explicitly instead of
    sleeping, so deadline expiry is exact and assertions never race."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _g(seed, scale=4, degree=4):
    return generators.generate("rmat", scale, avg_degree=degree, seed=seed)


# Seeds whose scale-4 rmat graphs all share the (n_pad=16, cap=32) pow2
# bucket — the same-bucket tests draw from this pool.
_POOL = (0, 2, 3, 4, 5, 6, 9, 10, 12, 13)


def _same_bucket(k):
    return [_g(s) for s in _POOL[:k]]


def _params(**kw):
    base = dict(serve_lanes=3, serve_max_wait_ms=50.0, serve_max_queue=6,
                batch_max_vertices=64, batch_max_edges=256)
    base.update(kw)
    return GHSParams(**base)


def _assert_oracle(graph, result):
    oracle = kruskal_ref.kruskal(graph)
    assert np.array_equal(result.edge_mask, oracle.edge_mask)
    assert result.num_components == oracle.num_components


# ---------------------------------------------------------------------------
# Dispatch triggers
# ---------------------------------------------------------------------------

def test_size_flush_fires_without_time_passing():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    graphs = _same_bucket(3)                 # one bucket: full at 3 lanes
    futs = [svc.submit(g) for g in graphs]
    assert not any(f.done() for f in futs)   # submit never dispatches
    assert svc.poll(now=0.0) == 1
    assert svc.stats.size_flushes == 1
    assert svc.stats.deadline_flushes == 0
    assert svc.stats.ghost_lanes == 0
    for g, f in zip(graphs, futs):
        assert f.done()
        _assert_oracle(g, f.result())


def test_deadline_flush_pads_ghost_lanes():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    fut = svc.submit(_g(7))
    # Under the deadline: nothing moves, however often we poll.
    assert svc.poll(now=0.049) == 0
    assert not fut.done()
    # At the deadline: the part-full bucket flushes, padded to 3 lanes.
    assert svc.poll(now=0.050) == 1
    assert svc.stats.deadline_flushes == 1
    assert svc.stats.size_flushes == 0
    assert svc.stats.ghost_lanes == 2
    assert fut.done()
    _assert_oracle(_g(7), fut.result())


def test_deadline_measured_from_oldest_request():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    svc.submit(_g(_POOL[0]))                 # t = 0
    clock.advance(0.04)
    svc.submit(_g(_POOL[1]))                 # t = 0.04, same bucket
    # 10 ms later the OLDEST is 50 ms old: both flush together.
    assert svc.poll(now=0.050) == 1
    assert svc.stats.deadline_flushes == 1
    assert svc.stats.completed == 2
    assert svc.stats.ghost_lanes == 1


def test_bit_identical_to_single_graph_solve():
    svc = MSTService(_params(), clock=FakeClock())
    graphs = _same_bucket(3)
    futs = [svc.submit(g) for g in graphs]
    svc.poll(now=0.0)
    for g, f in zip(graphs, futs):
        single, _ = mst_api.minimum_spanning_forest(g)
        assert np.array_equal(f.result().edge_mask, single.edge_mask)


def test_completion_in_arrival_order():
    svc = MSTService(_params(), clock=FakeClock())
    order = []
    for i, g in enumerate(_same_bucket(3)):
        fut = svc.submit(g)
        fut.add_done_callback(lambda f, i=i: order.append(i))
    svc.poll(now=0.0)
    assert order == [0, 1, 2]


def test_mixed_buckets_route_and_drain():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    graphs = [_g(1, scale=3), _g(2, scale=5), _g(3, scale=3),
              preprocess(np.zeros(0), np.zeros(0),
                         np.zeros(0, np.float32), 6)]
    futs = [svc.submit(g) for g in graphs]
    assert len(svc._queues) >= 2             # distinct shapes, own queues
    assert svc.poll(now=0.0) == 0            # none full, none expired
    assert svc.drain() == len(svc._queues)
    assert svc.stats.drain_flushes == len(svc._queues)
    for g, f in zip(graphs, futs):
        _assert_oracle(g, f.result())


# ---------------------------------------------------------------------------
# Backpressure
# ---------------------------------------------------------------------------

def test_oversize_shed_is_typed_and_counted():
    svc = MSTService(_params(batch_max_edges=8), clock=FakeClock())
    with pytest.raises(OversizeError, match="exceeds pack_batch capacity"):
        svc.submit(_g(3, scale=5, degree=8))
    assert svc.stats.shed_oversize == 1
    assert svc.stats.accepted == 0
    assert svc.queue_depth() == 0            # shed requests never queue


def test_queue_full_shed_then_poll_recovers():
    clock = FakeClock()
    svc = MSTService(_params(), clock=clock)
    futs = [svc.submit(g) for g in _same_bucket(6)]   # serve_max_queue
    with pytest.raises(QueueFullError, match="queue is full"):
        svc.submit(_g(_POOL[6]))
    assert svc.stats.shed_queue_full == 1
    assert svc.stats.max_queue_depth == 6
    # One poll drains the backlog as two size flushes; admission reopens.
    assert svc.poll(now=0.0) == 2
    assert svc.stats.size_flushes == 2
    assert all(f.done() for f in futs)
    svc.submit(_g(_POOL[7]))
    assert svc.stats.accepted == 7


def test_shed_rate_accounting():
    svc = MSTService(_params(batch_max_edges=8), clock=FakeClock())
    svc.submit(preprocess(np.array([0]), np.array([1]),
                          np.array([0.5], np.float32), 2))
    with pytest.raises(OversizeError):
        svc.submit(_g(3, scale=5, degree=8))
    assert svc.stats.shed == 1
    assert svc.stats.shed_rate == pytest.approx(0.5)


def test_service_rejects_inconsistent_knobs():
    with pytest.raises(ValueError, match="serve_lanes"):
        MSTService(_params(serve_lanes=0))
    with pytest.raises(ValueError, match="serve_max_queue"):
        MSTService(_params(serve_lanes=4, serve_max_queue=2))


# ---------------------------------------------------------------------------
# Warmup lattice
# ---------------------------------------------------------------------------

def test_warmup_covers_the_pow2_lattice():
    p = _params(batch_max_vertices=8, batch_max_edges=16)
    svc = MSTService(p, clock=FakeClock())
    # n_pad in {1, 2, 4, 8} x cap in {8, 16} = 8 shapes.
    assert svc.warmup() == 8
    assert svc.stats.buckets_warmed == 8
    # Warmup solves ghosts only: no request counters move.
    assert svc.stats.accepted == svc.stats.completed == 0
    assert svc.stats.flushes == 0


def test_warmup_skips_unbounded_and_exact_policies():
    assert MSTService(_params(batch_max_vertices=0, batch_max_edges=0),
                      clock=FakeClock()).warmup() == 0
    assert MSTService(
        _params(batch_bucket="exact"), clock=FakeClock()).warmup() == 0


# ---------------------------------------------------------------------------
# Poisson driver in virtual time
# ---------------------------------------------------------------------------

def test_run_poisson_virtual_time_deterministic():
    clock = FakeClock()
    svc = MSTService(_params(serve_max_queue=32), clock=clock)
    graphs = [_g(s, scale=3) for s in range(8)]
    futs = run_poisson(svc, graphs, rate=200.0, seed=1,
                       sleep=clock.advance)
    assert len(futs) == 8
    served = [f for f in futs if f is not None]
    assert len(served) == 8 - svc.stats.shed
    assert all(f.done() for f in served)
    assert svc.stats.completed == len(served)
    assert len(svc.stats.latencies_ms) == len(served)
    assert svc.stats.graphs_per_s > 0
    for g, f in zip(graphs, futs):
        if f is not None:
            _assert_oracle(g, f.result())


# ---------------------------------------------------------------------------
# Incremental admission primitives (pipeline.bucket_shape / pack_bucket)
# ---------------------------------------------------------------------------

def test_bucket_shape_matches_pack_batch_routing():
    graphs = [_g(1, scale=3), _g(2, scale=5), _g(3, scale=3)]
    for bucket in ("pow2", "exact"):
        batches = pipeline.pack_batch(graphs, bucket=bucket)
        routed = {}
        for i, g in enumerate(graphs):
            shape = pipeline.bucket_shape(g.num_vertices, g.num_edges,
                                          bucket=bucket)
            routed.setdefault(shape, []).append(i)
        assert routed == {(b.n_pad, b.cap): list(b.indices)
                          for b in batches}


def test_bucket_shape_raises_like_pack_batch():
    with pytest.raises(ValueError, match="unknown batch bucket policy"):
        pipeline.bucket_shape(4, 4, bucket="golf")
    with pytest.raises(ValueError, match="num_vertices=100 > max_vertices"):
        pipeline.bucket_shape(100, 4, max_vertices=64)
    with pytest.raises(ValueError, match="num_edges=500 > max_edges"):
        pipeline.bucket_shape(8, 500, max_edges=256)


def test_pack_bucket_validates_fit_and_indices():
    g = _g(5, scale=3)
    with pytest.raises(ValueError, match="does not fit bucket"):
        pipeline.pack_bucket([g], 2, 4)
    with pytest.raises(ValueError, match="indices length"):
        pipeline.pack_bucket([g], 8, 256, indices=(0, 1))
    with pytest.raises(ValueError, match="at least one graph"):
        pipeline.pack_bucket([], 8, 8)


def test_solve_packed_equals_batched_entry():
    graphs = [_g(s, scale=4) for s in range(4)]
    n_pad, cap = pipeline.bucket_shape(
        max(g.num_vertices for g in graphs),
        max(g.num_edges for g in graphs))
    batch = pipeline.pack_bucket(graphs, n_pad, cap)
    results, stats = mst_api.solve_packed(batch)
    ref, _ = mst_api.minimum_spanning_forests(graphs)
    for got, want in zip(results, ref):
        assert np.array_equal(got.edge_mask, want.edge_mask)
    assert stats.host_syncs == stats.intervals + stats.extra_syncs


def test_solve_packed_rejects_host_loop():
    g = _g(6, scale=3)
    batch = pipeline.pack_bucket([g], 8, 64)
    with pytest.raises(ValueError, match="round_loop='device'"):
        mst_api.solve_packed(
            batch, params=dataclasses.replace(GHSParams(),
                                              round_loop="host"))
