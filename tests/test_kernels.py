"""Per-kernel validation: shape/dtype sweeps, interpret-mode vs jnp oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

RNG = np.random.default_rng(0)


# --- segment_min -----------------------------------------------------------

@pytest.mark.parametrize("m,s", [(512, 3), (2048, 64), (4100, 257), (1024, 1)])
def test_segment_min_sweep(m, s):
    from repro.kernels.segment_min import ops, ref
    seg = np.sort(RNG.integers(0, s, m)).astype(np.int32)
    val = RNG.integers(0, 2**32 - 2, m, dtype=np.uint32)
    got = ops.segment_min_sorted(jnp.asarray(val), jnp.asarray(seg),
                                 num_segments=s, block=512)
    want = ref.segment_min(jnp.asarray(val), jnp.asarray(seg), s)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_segment_min_unsorted_path():
    from repro.kernels.segment_min import ops, ref
    m, s = 3000, 77
    seg = RNG.integers(0, s, m).astype(np.int32)
    val = RNG.integers(0, 2**32 - 2, m, dtype=np.uint32)
    got = ops.segment_min(jnp.asarray(val), jnp.asarray(seg),
                          num_segments=s, use_pallas=True)
    want = ref.segment_min(jnp.asarray(val), jnp.asarray(seg), s)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_segment_min_precomputed_order():
    """Passing a precomputed argsort(seg) matches the self-sorting path."""
    from repro.kernels.segment_min import ops, ref
    m, s = 2000, 33
    seg = RNG.integers(0, s, m).astype(np.int32)
    val = RNG.integers(0, 2**32 - 2, m, dtype=np.uint32)
    order = jnp.argsort(jnp.asarray(seg))
    got = ops.segment_min(jnp.asarray(val), jnp.asarray(seg),
                          num_segments=s, use_pallas=True, order=order)
    want = ref.segment_min(jnp.asarray(val), jnp.asarray(seg), s)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,s", [(512, 3), (2100, 64), (1024, 1)])
def test_segment_min64_packed_key_sweep(m, s):
    """Pair-lex Pallas scan over packed uint64 keys == uint64 scatter-min."""
    from jax.experimental import enable_x64
    from repro.kernels.segment_min import ops, ref
    seg = RNG.integers(0, s, m).astype(np.int32)
    key = ((RNG.integers(0, 2**31, m).astype(np.uint64) << np.uint64(32))
           | RNG.integers(0, 2**32 - 1, m).astype(np.uint64))
    with enable_x64():
        got = ops.segment_min64(jnp.asarray(key), jnp.asarray(seg),
                                num_segments=s, use_pallas=True)
        want = ref.segment_min64(jnp.asarray(key), jnp.asarray(seg), s)
        assert np.array_equal(np.asarray(got), np.asarray(want))


def test_segmented_min2_scan_matches_oracle():
    from jax.experimental import enable_x64
    from repro.kernels.segment_min import ref
    from repro.kernels.segment_min.segment_min import segmented_min2_scan
    m = 1024
    seg = np.sort(RNG.integers(0, 9, m)).astype(np.int32)
    hi = RNG.integers(0, 50, m, dtype=np.uint32)     # many hi-lane ties
    lo = RNG.integers(0, 2**32 - 2, m, dtype=np.uint32)
    with enable_x64():
        gh, gl = segmented_min2_scan(jnp.asarray(seg), jnp.asarray(hi),
                                     jnp.asarray(lo), block=512)
        wh, wl = ref.segmented_min2_scan(jnp.asarray(seg), jnp.asarray(hi),
                                         jnp.asarray(lo))
    assert np.array_equal(np.asarray(gh), np.asarray(wh))
    assert np.array_equal(np.asarray(gl), np.asarray(wl))


# --- edge_hash ---------------------------------------------------------------

@pytest.mark.parametrize("n", [100, 5000])
def test_edge_hash_sweep(n):
    from repro.kernels.edge_hash import ops
    lv = RNG.integers(0, 997, n).astype(np.int32)
    u = RNG.integers(0, 99991, n).astype(np.int32)
    pairs = sorted({(a, b) for a, b in zip(lv, u)})
    lv = np.array([p[0] for p in pairs], np.int32)
    u = np.array([p[1] for p in pairs], np.int32)
    pos = np.arange(len(lv), dtype=np.int32)
    table = ops.build_table(lv, u, pos, int(len(lv) * 4.23) | 1)
    q_lv = np.concatenate([lv, lv + 7919])
    q_u = np.concatenate([u, u])
    got = np.asarray(ops.lookup(table, q_lv, q_u, use_pallas=True))
    d = {(a, b): p for a, b, p in zip(lv, u, pos)}
    want = np.array([d.get((a, b), -1) for a, b in zip(q_lv, q_u)], np.int32)
    assert np.array_equal(got, want)


# --- flash attention ---------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d,dtype", [
    (1, 4, 4, 256, 64, jnp.float32),
    (2, 8, 2, 512, 128, jnp.float32),
    (1, 4, 1, 256, 64, jnp.bfloat16),
])
def test_flash_attention_sweep(b, hq, hkv, s, d, dtype):
    from repro.kernels.flash_attention import ops, ref
    q = jnp.asarray(RNG.standard_normal((b, hq, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    want = ref.attention(q, k, v).astype(jnp.float32)
    got = ops.attention(q, k, v, use_pallas=True).astype(jnp.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    assert float(jnp.abs(got - want).max()) < tol


def test_blocked_attention_matches_ref():
    from repro.kernels.flash_attention import ops, ref
    q = jnp.asarray(RNG.standard_normal((1, 4, 2048, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 2048, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 2048, 64)), jnp.float32)
    want = ref.attention(q, k, v)
    got = ops.blocked_attention(q, k, v, q_chunk=256, kv_chunk=512)
    assert float(jnp.abs(got - want).max()) < 2e-3


def test_attention_noncausal():
    from repro.kernels.flash_attention import ops, ref
    q = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    want = ref.attention(q, k, v, causal=False)
    got = ops.attention(q, k, v, causal=False, use_pallas=True)
    assert float(jnp.abs(got - want).max()) < 2e-3


# --- decode attention --------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d", [(2, 8, 2, 1024, 64),
                                          (1, 4, 4, 2048, 128)])
def test_decode_attention_sweep(b, hq, hkv, s, d):
    from repro.kernels.decode_attention import ops, ref
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    ln = jnp.asarray(RNG.integers(1, s, b), jnp.int32)
    want = ref.decode_attention(q, k, v, ln)
    for impl in ("pallas", "grouped", "chunked"):
        if impl == "pallas":
            got = ops.decode_attention(q, k, v, ln, use_pallas=True)
        elif impl == "grouped":
            got = ops.grouped_decode_attention(q, k, v, ln)
        else:
            got = ops.chunked_decode_attention(q, k, v, ln, chunk=256)
        assert float(jnp.abs(got - want).max()) < 2e-3, impl


# --- rwkv6 -------------------------------------------------------------------

@pytest.mark.parametrize("bh,t,d", [(2, 128, 32), (4, 256, 64)])
def test_wkv6_sweep(bh, t, d):
    from repro.kernels.rwkv6 import ops, ref
    r = jnp.asarray(RNG.standard_normal((bh, t, d)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, t, d)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, t, d)) * 0.5, jnp.float32)
    w = jnp.asarray(RNG.uniform(0.8, 0.999, (bh, t, d)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((bh, d)) * 0.3, jnp.float32)
    want = ref.wkv6(r, k, v, w, u)
    got = ops.wkv6(r, k, v, w, u, use_pallas=True, chunk=64)
    assert float(jnp.abs(got - want).max()) < 1e-3


def test_wkv6_step_consistency():
    from repro.kernels.rwkv6 import ops, ref
    bh, t, d = 2, 16, 16
    r, k, v = (jnp.asarray(RNG.standard_normal((bh, t, d)) * 0.5,
                           jnp.float32) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.8, 0.99, (bh, t, d)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((bh, d)) * 0.3, jnp.float32)
    want, s_want = ref.wkv6(r, k, v, w, u, return_state=True)
    s = jnp.zeros((bh, d, d))
    outs = []
    for i in range(t):
        s, o = ops.wkv6_step(s, r[:, i], k[:, i], v[:, i], w[:, i], u)
        outs.append(o)
    assert float(jnp.abs(jnp.stack(outs, 1) - want).max()) < 1e-3
    assert float(jnp.abs(s - s_want).max()) < 1e-3


# --- mamba scan --------------------------------------------------------------

@pytest.mark.parametrize("b,t,dim,n", [(1, 128, 32, 8), (2, 256, 64, 16)])
def test_selective_scan_sweep(b, t, dim, n):
    from repro.kernels.mamba_scan import ops, ref
    x = jnp.asarray(RNG.standard_normal((b, t, dim)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, t, dim)), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, t, n)) * 0.5, jnp.float32)
    cc = jnp.asarray(RNG.standard_normal((b, t, n)) * 0.5, jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, (dim, n)), jnp.float32)
    d = jnp.asarray(RNG.standard_normal(dim) * 0.1, jnp.float32)
    want = ref.selective_scan(x, dt, bb, cc, a, d)
    got = ops.selective_scan(x, dt, bb, cc, a, d, use_pallas=True, chunk=64)
    assert float(jnp.abs(got - want).max()) < 1e-3


def test_selective_scan_step_consistency():
    from repro.kernels.mamba_scan import ops, ref
    b, t, dim, n = 1, 12, 16, 4
    x = jnp.asarray(RNG.standard_normal((b, t, dim)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.1, (b, t, dim)), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, t, n)) * 0.5, jnp.float32)
    cc = jnp.asarray(RNG.standard_normal((b, t, n)) * 0.5, jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, (dim, n)), jnp.float32)
    d = jnp.asarray(RNG.standard_normal(dim) * 0.1, jnp.float32)
    want, h_want = ref.selective_scan(x, dt, bb, cc, a, d, return_state=True)
    h = jnp.zeros((b, dim, n))
    outs = []
    for i in range(t):
        h, y = ref.selective_scan_step(h, x[:, i], dt[:, i], bb[:, i],
                                       cc[:, i], a, d)
        outs.append(y)
    assert float(jnp.abs(jnp.stack(outs, 1) - want).max()) < 1e-3
    assert float(jnp.abs(h - h_want).max()) < 1e-3


# --- segment_min edge cases (regressions) ------------------------------------

def test_segment_min_empty_input_returns_inf():
    """m == 0 must return INF sentinels without reaching a zero-grid
    pallas_call (interpret mode tolerates one, compiled lowering does not)."""
    from jax.experimental import enable_x64
    from repro.kernels.segment_min import ops
    empty32 = jnp.zeros(0, jnp.uint32)
    emptyseg = jnp.zeros(0, jnp.int32)
    got = ops.segment_min_sorted(empty32, emptyseg, num_segments=7)
    assert np.array_equal(np.asarray(got), np.full(7, 0xFFFFFFFF, np.uint32))
    got = ops.segment_min(empty32, emptyseg, num_segments=7, use_pallas=True)
    assert np.array_equal(np.asarray(got), np.full(7, 0xFFFFFFFF, np.uint32))
    with enable_x64():
        got = ops.segment_min64_sorted(jnp.zeros(0, jnp.uint64), emptyseg,
                                       num_segments=5)
        assert np.array_equal(np.asarray(got),
                              np.full(5, ops.INF_U64, np.uint64))
        got = ops.segment_min64(jnp.zeros(0, jnp.uint64), emptyseg,
                                num_segments=5, use_pallas=True)
        assert np.array_equal(np.asarray(got),
                              np.full(5, ops.INF_U64, np.uint64))


def test_segment_min_zero_segments():
    from repro.kernels.segment_min import ops
    got = ops.segment_min_sorted(
        jnp.asarray([3, 1], jnp.uint32), jnp.asarray([0, 1], jnp.int32),
        num_segments=0)
    assert got.shape == (0,)


def test_segment_min_fully_masked_inputs_return_inf():
    """All-PAD_VERTEX segments (every lane is engine padding): every real
    segment must come back INF — the sentinel run may not leak into any
    output slot."""
    from jax.experimental import enable_x64
    from repro.core.graph import PAD_VERTEX
    from repro.kernels.segment_min import ops
    m, s = 1000, 13
    seg = np.full(m, PAD_VERTEX, np.int32)
    val = np.full(m, 0xFFFFFFFF, np.uint32)
    got = ops.segment_min(jnp.asarray(val), jnp.asarray(seg),
                          num_segments=s, use_pallas=True)
    assert np.array_equal(np.asarray(got), np.full(s, 0xFFFFFFFF, np.uint32))
    with enable_x64():
        key = np.full(m, ops.INF_U64, np.uint64)
        got = ops.segment_min64(jnp.asarray(key), jnp.asarray(seg),
                                num_segments=s, use_pallas=True)
        assert np.array_equal(np.asarray(got),
                              np.full(s, ops.INF_U64, np.uint64))


# --- spmv_minplus (fused Borůvka round body, DESIGN.md §9) -------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                        # container ships without hypothesis
    HAVE_HYPOTHESIS = False


def _election_case(rng, *, all_equal=False, dup_keys=False, ragged=False):
    """One CSR-shaped election layout: endpoint fragment labels + packed
    keys, with dead edges, INF padding lanes, optional duplicate keys /
    all-equal weights / ragged (skewed) segment sizes."""
    n = int(rng.integers(1, 50))
    m = int(rng.integers(0, 300))
    cs = rng.integers(0, n, m).astype(np.uint32)
    cd = rng.integers(0, n, m).astype(np.uint32)
    if ragged and m:
        # Pile half the edges onto a few fragments → long and empty runs.
        cs[: m // 2] = rng.integers(0, max(n // 8, 1), m // 2)
    if all_equal:
        wbits = np.full(m, 0x3F000000, np.uint64)       # bits of 0.5f
    else:
        wbits = rng.integers(0, 1 << 29, m).astype(np.uint64)
    eid = np.arange(m, dtype=np.uint64)
    if dup_keys and m:
        eid = rng.integers(0, max(m // 3, 1), m).astype(np.uint64)
    key = (wbits << np.uint64(32)) | eid
    if m:
        key[rng.random(m) < 0.15] = np.uint64(0xFFFFFFFFFFFFFFFF)
        dead = rng.random(m) < 0.2
        cd[dead] = cs[dead]                              # self-fragment edges
    return n, cs, cd, key


def _assert_elect_lowerings_agree(n, cs, cd, key):
    from jax.experimental import enable_x64
    from repro.kernels.spmv_minplus import ops
    m = key.shape[0]
    with enable_x64():
        args = (jnp.asarray(cs), jnp.asarray(cd), jnp.asarray(key))
        want = ops.elect(*args, num_segments=n, lowering="scatter")
        sort_bits = ops.sort_gate(n, max(m, 1))
        got_sort = ops.elect(*args, num_segments=n, lowering="sort",
                             sort_bits=sort_bits)
        got_pallas = ops.elect(*args, num_segments=n, lowering="pallas",
                               block=128)
        assert np.array_equal(np.asarray(want), np.asarray(got_sort))
        assert np.array_equal(np.asarray(want), np.asarray(got_pallas))


@pytest.mark.parametrize("case", ["plain", "ragged", "dup_keys", "all_equal"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_elect_lowerings_agree_seeded(case, seed):
    """scatter/sort/pallas(interpret) elections are bit-identical across
    ragged segments, duplicate keys, and all-equal weights (seeded sweep —
    the hypothesis variant below widens this when hypothesis is present)."""
    rng = np.random.default_rng(1000 * seed + hash(case) % 997)
    n, cs, cd, key = _election_case(
        rng, all_equal=(case == "all_equal"), dup_keys=(case == "dup_keys"),
        ragged=(case == "ragged"))
    _assert_elect_lowerings_agree(n, cs, cd, key)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.booleans(), st.booleans(),
           st.booleans())
    def test_elect_lowerings_agree_hypothesis(seed, all_equal, dup_keys,
                                              ragged):
        rng = np.random.default_rng(seed)
        n, cs, cd, key = _election_case(rng, all_equal=all_equal,
                                        dup_keys=dup_keys, ragged=ragged)
        _assert_elect_lowerings_agree(n, cs, cd, key)


def test_masked_minplus_scan_matches_masked_oracle():
    """The in-kernel mask == pre-masking the lanes then running the
    unmasked pair-lex scan oracle."""
    from jax.experimental import enable_x64
    from repro.kernels.segment_min import ref as segref
    from repro.kernels.spmv_minplus.spmv_minplus import masked_minplus_scan
    rng = np.random.default_rng(7)
    m = 1024
    seg = np.sort(rng.integers(0, 11, m)).astype(np.int32)
    oth = rng.integers(0, 11, m).astype(np.int32)
    hi = rng.integers(0, 40, m, dtype=np.uint32)       # many hi-lane ties
    lo = rng.integers(0, 2**32 - 2, m, dtype=np.uint32)
    inf = np.uint32(0xFFFFFFFF)
    hi[rng.random(m) < 0.1] = inf                      # INF padding lanes
    lo[hi == inf] = inf
    with enable_x64():
        gh, gl = masked_minplus_scan(
            jnp.asarray(seg), jnp.asarray(oth), jnp.asarray(hi),
            jnp.asarray(lo), block=256)
        live = (seg != oth) & ~((hi == inf) & (lo == inf))
        mh = np.where(live, hi, inf).astype(np.uint32)
        ml = np.where(live, lo, inf).astype(np.uint32)
        wh, wl = segref.segmented_min2_scan(
            jnp.asarray(seg), jnp.asarray(mh), jnp.asarray(ml))
    assert np.array_equal(np.asarray(gh), np.asarray(wh))
    assert np.array_equal(np.asarray(gl), np.asarray(wl))


def test_shortcut_relabel_kernel_matches_ref():
    from repro.kernels.spmv_minplus import ops, ref
    rng = np.random.default_rng(11)
    for n in (1, 2, 97, 1024):
        # hook_min-shaped forests: parent[i] <= i.
        parent = np.minimum(rng.integers(0, n, n), np.arange(n)).astype(
            np.uint32)
        comp = rng.integers(0, n, n).astype(np.uint32)
        want = ref.shortcut_relabel(jnp.asarray(parent), jnp.asarray(comp))
        got = ops.shortcut_relabel(jnp.asarray(parent), jnp.asarray(comp),
                                   use_pallas=True)
        assert np.array_equal(np.asarray(got), np.asarray(want)), n
        # Fully compressed: every label points at its root.
        root = parent.copy()
        for _ in range(max(int(np.ceil(np.log2(max(n, 2)))), 1)):
            root = root[root]
        assert np.array_equal(np.asarray(got), root[comp]), n
