"""Incremental MST (DESIGN.md §13): bit-identity of apply_updates vs a
from-scratch re-solve, the cycle/cut probe's certificates, the update
stats ledger, and shard-count invariance.

Randomized-batch budget (the acceptance floor is 200 batches over ≥ 3
scenario kinds × 1/2/4 shards):
  * in-process streams:  3 kinds × 4 seeds × 12 chained batches = 144
  * subprocess shard sweep: 3 shard counts × 3 kinds × 8 batches =  72
                                                              -----
                                                               216
Every batch is checked bit-identical against BOTH the Kruskal oracle and
a plain Borůvka re-solve of the merged graph (the definition of the
updated graph — `apply_edge_batch`)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import generators, kruskal_ref, runtime
from repro.core.graph import PAD_VERTEX, preprocess
from repro.core.incremental import (EdgeBatch, IncrementalForest,
                                    _apply_edge_batch_reference,
                                    apply_edge_batch, apply_updates,
                                    finalize_plan, plan_updates)
from repro.core.mst_api import (incremental_forest, minimum_spanning_forest,
                                minimum_spanning_forests)
from repro.core import mst_api
from repro.core.params import GHSParams
from repro.kernels.spmv_minplus import ops as minplus_ops

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

STREAM_KINDS = ("rmat", "grid", "chain")
STREAM_SEEDS = (0, 1, 2, 3)
STREAM_BATCHES = 12


def run_child(code: str, devices: int = 4) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def _assert_identical(got, want, g, ctx):
    assert np.array_equal(got.edge_mask, want.edge_mask), ctx
    assert np.array_equal(
        np.sort(g.weight[got.edge_mask].view(np.uint32)),
        np.sort(g.weight[want.edge_mask].view(np.uint32))), ctx
    assert got.num_components == want.num_components, ctx
    assert got.num_tree_edges == want.num_tree_edges, ctx


def _solve(graph, params=None) -> IncrementalForest:
    state, _ = incremental_forest(
        graph, params=params or GHSParams())
    return state


def _check_update(state, batch, params=None, ctx=None):
    """apply_updates == Kruskal == plain Borůvka on the merged graph."""
    params = params or GHSParams()
    new_state, st = apply_updates(state, batch, params=params)
    g2 = apply_edge_batch(state.graph, batch)
    want = kruskal_ref.kruskal(g2)
    plain, _ = minimum_spanning_forest(g2, method="boruvka")
    _assert_identical(new_state.forest, want, g2, ctx)
    _assert_identical(new_state.forest, plain, g2, ctx)
    # stats protocol: the probe's fused readback + the sub-solve's syncs
    assert st.host_syncs == st.intervals + st.extra_syncs, ctx
    assert 0 <= st.candidate_count <= g2.num_edges, ctx
    return new_state, st


def _random_batch(rng, state, n_ins=6, n_tree_del=2, n_rand_del=2):
    """Inserts + tree-edge deletes + arbitrary-pair deletes."""
    g = state.graph
    n = g.num_vertices
    ins = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
            float(rng.random() * 0.98 + 0.01)) for _ in range(n_ins)]
    dels = []
    tree = np.flatnonzero(state.forest.edge_mask)
    if tree.size and n_tree_del:
        for i in rng.choice(tree, size=min(n_tree_del, tree.size),
                            replace=False):
            dels.append((int(g.src[i]), int(g.dst[i])))
    dels += [(int(rng.integers(0, n)), int(rng.integers(0, n)))
             for _ in range(n_rand_del)]
    return EdgeBatch.make(ins, dels)


# ---------------------------------------------------------------------------
# EdgeBatch contract
# ---------------------------------------------------------------------------

def test_edge_batch_make_and_counts():
    b = EdgeBatch.make([(0, 1, 0.5), (2, 3, 0.25)], [(4, 5)])
    assert (b.num_inserts, b.num_deletes, b.size) == (2, 1, 3)
    assert b.insert_weight.dtype == np.float32
    empty = EdgeBatch.make()
    assert empty.size == 0


def test_edge_batch_validation():
    with pytest.raises(ValueError, match="endpoints"):
        EdgeBatch.make([(0, 99, 0.5)]).validate(16)
    with pytest.raises(ValueError, match="endpoints"):
        EdgeBatch.make([], [(-1, 3)]).validate(16)
    with pytest.raises(ValueError, match=r"\(0, 1\)"):
        EdgeBatch.make([(0, 1, 1.5)]).validate(16)
    with pytest.raises(ValueError, match=r"\(0, 1\)"):
        EdgeBatch.make([(0, 1, 0.0)]).validate(16)
    EdgeBatch.make([(0, 15, 0.5)]).validate(16)   # in-range is fine


def test_empty_batch_is_identity():
    state = _solve(generators.generate("rmat", 6, seed=1))
    new_state, st = _check_update(state, EdgeBatch.make(), ctx="empty")
    assert st.updates_applied == 0
    assert np.array_equal(new_state.forest.edge_mask,
                          state.forest.edge_mask)
    # the merged graph IS the old graph (canonical form is a fixpoint)
    assert np.array_equal(new_state.graph.src, state.graph.src)
    assert np.array_equal(new_state.graph.weight.view(np.uint32),
                          state.graph.weight.view(np.uint32))


# ---------------------------------------------------------------------------
# Adversarial single-batch cases
# ---------------------------------------------------------------------------

def test_self_loop_insert_is_noop():
    state = _solve(generators.generate("rmat", 6, seed=2))
    _, st = _check_update(
        state, EdgeBatch.make([(3, 3, 0.5), (7, 7, 0.01)]), ctx="loops")
    assert st.updates_applied == 0


def test_duplicate_inserts_keep_min_weight():
    """The same pair inserted twice in one batch: §3.1 preprocess keeps the
    minimum copy, and the probe sees only the canonical edge."""
    state = _solve(generators.generate("rmat", 6, seed=3))
    g = state.graph
    # a pair not present in the old graph
    u, v = 0, 1
    pid = set(zip(g.src.tolist(), g.dst.tolist()))
    while (u, v) in pid or (v, u) in pid or u == v:
        v += 1
    batch = EdgeBatch.make([(u, v, 0.7), (v, u, 0.2), (u, v, 0.9)])
    new_state, st = _check_update(state, batch, ctx="dup-insert")
    i = np.flatnonzero((new_state.graph.src == u)
                       & (new_state.graph.dst == v))
    assert i.size == 1
    assert new_state.graph.weight[i[0]] == np.float32(0.2)
    assert st.updates_applied == 1        # ONE structural change


def test_parallel_insert_of_existing_edge():
    """Inserting a heavier copy of an existing pair is structurally a
    no-op (the survivor wins); a lighter copy re-weights the pair and
    voids its old certificate."""
    state = _solve(generators.generate("rmat", 6, seed=4))
    g = state.graph
    i = int(np.flatnonzero(state.forest.edge_mask)[0])
    u, v, w = int(g.src[i]), int(g.dst[i]), float(g.weight[i])
    # heavier copy: nothing changes
    _, st = _check_update(
        state, EdgeBatch.make([(u, v, min(w + 0.01, 0.99))]), ctx="heavier")
    assert st.updates_applied == 0
    # lighter copy: one re-weight, and the (still lightest-path) edge stays
    new_state, st = _check_update(
        state, EdgeBatch.make([(u, v, w / 2)]), ctx="lighter")
    assert st.updates_applied == 1


def test_insert_existing_forest_edge_same_weight_is_noop():
    state = _solve(generators.generate("rmat", 6, seed=5))
    g = state.graph
    i = int(np.flatnonzero(state.forest.edge_mask)[3])
    batch = EdgeBatch.make([(int(g.src[i]), int(g.dst[i]),
                             float(g.weight[i]))])
    new_state, st = _check_update(state, batch, ctx="reinsert-tree")
    assert st.updates_applied == 0
    assert np.array_equal(new_state.forest.edge_mask,
                          state.forest.edge_mask)


def test_delete_non_tree_edge_keeps_forest():
    state = _solve(generators.generate("rmat", 6, seed=6))
    g = state.graph
    non_tree = np.flatnonzero(~state.forest.edge_mask)
    i = int(non_tree[0])
    batch = EdgeBatch.make([], [(int(g.src[i]), int(g.dst[i]))])
    new_state, st = _check_update(state, batch, ctx="del-non-tree")
    assert st.updates_applied == 1
    # same tree weights (canonical ids shifted, so compare the multiset)
    assert np.array_equal(
        np.sort(g.weight[state.forest.edge_mask].view(np.uint32)),
        np.sort(new_state.graph.weight[
            new_state.forest.edge_mask].view(np.uint32)))
    assert new_state.forest.num_components == state.forest.num_components


def test_delete_absent_pair_is_noop():
    state = _solve(generators.generate("rmat", 6, seed=7))
    g = state.graph
    pid = set(zip(g.src.tolist(), g.dst.tolist()))
    u, v = 0, 1
    while (u, v) in pid or u == v:
        v += 1
    _, st = _check_update(
        state, EdgeBatch.make([], [(u, v), (5, 5)]), ctx="del-absent")
    assert st.updates_applied == 0


def test_delete_bridge_without_replacement_splits_forest():
    """chain: every edge is a bridge with NO replacement — the severed
    component stays severed and the component count grows."""
    state = _solve(generators.generate("chain", 5, seed=0))
    g = state.graph
    i = int(np.flatnonzero(state.forest.edge_mask)[4])
    batch = EdgeBatch.make([], [(int(g.src[i]), int(g.dst[i]))])
    new_state, st = _check_update(state, batch, ctx="bridge")
    assert new_state.forest.num_components \
        == state.forest.num_components + 1
    assert st.replacement_probes == 0     # nothing crosses the cut


def test_delete_tree_edge_with_replacement_probes_the_cut():
    """A deleted tree edge whose cut has crossing non-tree edges: the cut
    probe counts them and the final solve elects the lightest."""
    state = _solve(generators.generate("rmat", 6, seed=8))
    g = state.graph
    tree = np.flatnonzero(state.forest.edge_mask)
    # find a tree edge with at least one replacement: delete and check
    for i in tree[:8]:
        batch = EdgeBatch.make([], [(int(g.src[i]), int(g.dst[i]))])
        new_state, st = _check_update(state, batch, ctx=("cut", int(i)))
        if new_state.forest.num_components \
                == state.forest.num_components:
            assert st.replacement_probes > 0
            return
    pytest.fail("no replaceable tree edge found in the first 8")


def test_delete_and_reinsert_same_pair_in_one_batch():
    """ISSUE contract: a pair both deleted and inserted is deleted from
    the OLD graph first, then re-inserted (possibly re-weighted)."""
    state = _solve(generators.generate("rmat", 6, seed=9))
    g = state.graph
    i = int(np.flatnonzero(state.forest.edge_mask)[0])
    u, v = int(g.src[i]), int(g.dst[i])
    batch = EdgeBatch.make([(u, v, 0.995)], [(u, v)])
    new_state, st = _check_update(state, batch, ctx="del+ins")
    j = np.flatnonzero((new_state.graph.src == u)
                       & (new_state.graph.dst == v))
    assert j.size == 1
    assert new_state.graph.weight[j[0]] == np.float32(0.995)


def test_update_from_empty_graph_builds_forest():
    """No anchor forest exists — the keep-all path solves from scratch."""
    g0 = preprocess(np.zeros(0, np.int64), np.zeros(0, np.int64),
                    np.zeros(0, np.float32), 8)
    state = _solve(g0)
    assert state.forest.num_components == 8
    batch = EdgeBatch.make([(i, i + 1, 0.1 * (i + 1)) for i in range(7)])
    new_state, st = _check_update(state, batch, ctx="from-empty")
    assert new_state.forest.num_components == 1
    assert st.updates_applied == 7


def test_delete_every_edge_empties_the_graph():
    state = _solve(generators.generate("chain", 4, seed=1))
    g = state.graph
    batch = EdgeBatch.make(
        [], [(int(u), int(v)) for u, v in zip(g.src, g.dst)])
    new_state, st = _check_update(state, batch, ctx="delete-all")
    assert new_state.graph.num_edges == 0
    assert new_state.forest.num_components == g.num_vertices


def test_sorted_merge_matches_preprocess_reference():
    """apply_edge_batch's sorted-merge fast path must be bit-identical to
    the preprocess-based definition across deletes, colliding inserts
    (lighter, heavier, AND exact-tie copies), duplicate inserts,
    self-loops, and empty graphs."""
    rng = np.random.default_rng(11)
    for trial in range(30):
        n = int(rng.integers(2, 64))
        m = int(rng.integers(0, 150))
        g = preprocess(rng.integers(0, n, m), rng.integers(0, n, m),
                       rng.random(m, dtype=np.float32) * 0.98 + 0.01, n)
        ins = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
                float(rng.random() * 0.98 + 0.01))
               for _ in range(int(rng.integers(0, 10)))]
        if g.num_edges:                       # exact-tie + heavier + lighter
            i = int(rng.integers(0, g.num_edges))
            w = float(g.weight[i])
            ins += [(int(g.src[i]), int(g.dst[i]), w),
                    (int(g.dst[i]), int(g.src[i]), min(w * 1.5, 0.99)),
                    (int(g.src[i]), int(g.dst[i]), w / 2)]
        if ins:                               # duplicate insert pair
            ins.append(ins[0])
        dels = [(int(rng.integers(0, n)), int(rng.integers(0, n)))
                for _ in range(int(rng.integers(0, 5)))]
        if g.num_edges:
            j = int(rng.integers(0, g.num_edges))
            dels.append((int(g.dst[j]), int(g.src[j])))
        batch = EdgeBatch.make(ins, dels)
        got = apply_edge_batch(g, batch)
        want = _apply_edge_batch_reference(g, batch)
        assert got.num_edges == want.num_edges, trial
        assert np.array_equal(got.src, want.src), trial
        assert np.array_equal(got.dst, want.dst), trial
        assert np.array_equal(got.weight.view(np.uint32),
                              want.weight.view(np.uint32)), trial


def test_adversarial_corpus_updates_exact():
    from test_mst_correctness import _adversarial_corpus
    rng = np.random.default_rng(0)
    for name, g in _adversarial_corpus():
        state = _solve(g)
        _check_update(state, _random_batch(rng, state), ctx=name)


# ---------------------------------------------------------------------------
# Stats ledger
# ---------------------------------------------------------------------------

def test_updates_applied_counts_structural_changes_exactly():
    state = _solve(generators.generate("rmat", 6, seed=10))
    g = state.graph
    tree = np.flatnonzero(state.forest.edge_mask)
    i, j = int(tree[0]), int(tree[1])
    pid = set(zip(g.src.tolist(), g.dst.tolist()))
    u, v = 0, 1
    while (u, v) in pid or u == v:
        v += 1
    batch = EdgeBatch.make(
        inserts=[(u, v, 0.5),                                # added
                 (int(g.src[j]), int(g.dst[j]),
                  float(g.weight[j]) / 2),                   # re-weighted
                 (3, 3, 0.5)],                               # loop: no-op
        deletes=[(int(g.src[i]), int(g.dst[i]))])            # removed
    _, st = _check_update(state, batch, ctx="ledger")
    assert st.updates_applied == 3
    assert st.filter_passes == 1
    assert st.edges_filtered \
        == apply_edge_batch(g, batch).num_edges - st.candidate_count


def test_probe_shrinks_the_final_solve():
    """The point of the pass: on a mostly-unchanged graph the certificates
    drop a large share of edges before the final solve."""
    state = _solve(generators.generate("rmat", 8, seed=0))
    rng = np.random.default_rng(1)
    _, st = _check_update(state, _random_batch(rng, state),
                          params=GHSParams(update_levels=32), ctx="shrink")
    assert st.candidate_count < state.graph.num_edges // 2
    assert st.edges_filtered > 0


def test_plan_finalize_split_matches_apply_updates():
    """The serving layer's path — plan, solve the candidates separately
    (batched), finalize — is bit-identical to the one-call façade."""
    state = _solve(generators.generate("rmat", 6, seed=11))
    rng = np.random.default_rng(2)
    batch = _random_batch(rng, state)
    plan = plan_updates(state, batch)
    forests, _ = minimum_spanning_forests([plan.sub])
    via_plan = finalize_plan(plan, forests[0])
    direct, _ = apply_updates(state, batch)
    assert np.array_equal(via_plan.forest.edge_mask,
                          direct.forest.edge_mask)
    assert via_plan.forest.num_components == direct.forest.num_components


# ---------------------------------------------------------------------------
# Param surfaces: both engines' knobs flow through the final solve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,params", [
    ("default", GHSParams()),
    ("pallas-round", GHSParams(round_kernel="pallas")),
    ("pallas-segmin", GHSParams(use_pallas=True)),
    ("host-loop", GHSParams(round_loop="host")),
    ("no-compaction", GHSParams(compaction="none")),
    ("hashed", GHSParams(partitioner="hashed")),
    ("levels-1", GHSParams(update_levels=1)),
    ("levels-64", GHSParams(update_levels=64)),
])
def test_param_surface_identical(name, params):
    g = generators.generate("rmat", 6, seed=12)
    state, _ = incremental_forest(g, params=params)
    rng = np.random.default_rng(3)
    _check_update(state, _random_batch(rng, state), params=params,
                  ctx=name)


def test_handle_from_any_engine_is_equivalent():
    """Forests are bit-identical across engines, so a handle solved with
    GHS or filter-Borůvka updates identically to the Borůvka one."""
    g = generators.generate("rmat", 6, seed=13)
    rng = np.random.default_rng(4)
    batch = _random_batch(rng, state=_solve(g))
    masks = {}
    for method in ("boruvka", "ghs", "filter_boruvka"):
        state, _ = incremental_forest(g, method=method)
        new_state, _ = mst_api.apply_updates(state, batch)
        masks[method] = new_state.forest.edge_mask
    assert np.array_equal(masks["boruvka"], masks["ghs"])
    assert np.array_equal(masks["boruvka"], masks["filter_boruvka"])


def test_update_levels_sweep_identical():
    """The level count quantizes the cycle certificate — it may change the
    candidate count, never the forest."""
    state = _solve(generators.generate("rmat", 7, seed=14))
    rng = np.random.default_rng(5)
    batch = _random_batch(rng, state)
    masks = []
    for levels in (1, 4, 16, 64):
        new_state, _ = apply_updates(
            state, batch, params=GHSParams(update_levels=levels))
        masks.append(new_state.forest.edge_mask)
    for m in masks[1:]:
        assert np.array_equal(m, masks[0])


# ---------------------------------------------------------------------------
# component_maxkey vs a union-find oracle
# ---------------------------------------------------------------------------

def _oracle_maxkey(n, src, dst, key, active):
    dsu = kruskal_ref._DSU(n)
    for u, v, a in zip(src, dst, active):
        if a:
            dsu.union(int(u), int(v))
    root = np.asarray([dsu.find(v) for v in range(n)])
    mx = np.zeros(n, dtype=np.uint64)
    for u, k, a in zip(src, key, active):
        if a:
            r = root[int(u)]
            mx[r] = max(mx[r], np.uint64(k))
    return mx[root]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_component_maxkey_matches_union_find(seed):
    from jax.experimental import enable_x64
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 100))
    m = int(rng.integers(0, 300))
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    key = rng.integers(1, 2**63, size=m, dtype=np.uint64)
    active = rng.random(m) < 0.5
    with enable_x64():
        comp, mk = minplus_ops.component_maxkey(
            src, dst, np.asarray(key), active, num_vertices=n)
    want = _oracle_maxkey(n, src, dst, key, active)
    assert np.array_equal(np.asarray(mk), want), seed
    # warm-start from the converged labels: bit-identical result
    with enable_x64():
        comp2, mk2 = minplus_ops.component_maxkey(
            src, dst, np.asarray(key), active, num_vertices=n,
            init=comp)
    assert np.array_equal(np.asarray(comp2), np.asarray(comp))
    assert np.array_equal(np.asarray(mk2), np.asarray(mk))


def test_component_maxkey_padding_inert():
    """PAD_VERTEX lanes with active=False never reach the scatter-max."""
    from jax.experimental import enable_x64
    src = np.asarray([0, 2, PAD_VERTEX], np.int32)
    dst = np.asarray([1, 3, PAD_VERTEX], np.int32)
    key = np.asarray([7, 9, 2**63 - 1], np.uint64)
    active = np.asarray([True, True, False])
    with enable_x64():
        comp, mk = minplus_ops.component_maxkey(
            src, dst, key, active, num_vertices=5)
    assert np.array_equal(np.asarray(comp), [0, 0, 2, 2, 4])
    assert np.array_equal(np.asarray(mk), [7, 7, 9, 9, 0])


# ---------------------------------------------------------------------------
# Randomized interleaved streams (3 kinds × 4 seeds × 12 chained batches)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", STREAM_KINDS)
@pytest.mark.parametrize("seed", STREAM_SEEDS)
def test_randomized_update_stream(kind, seed):
    rng = np.random.default_rng(1000 + seed)
    state = _solve(generators.generate(kind, 6, seed=seed))
    for step in range(STREAM_BATCHES):
        batch = _random_batch(
            rng, state,
            n_ins=int(rng.integers(0, 8)),
            n_tree_del=int(rng.integers(0, 3)),
            n_rand_del=int(rng.integers(0, 3)))
        state, _ = _check_update(state, batch, ctx=(kind, seed, step))


# ---------------------------------------------------------------------------
# Hypothesis property test
# ---------------------------------------------------------------------------

def test_incremental_property_randomized():
    pytest.importorskip(
        "hypothesis",
        reason="optional dev dependency (see requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st_

    @st_.composite
    def cases(draw):
        n = draw(st_.integers(min_value=2, max_value=40))
        m = draw(st_.integers(min_value=0, max_value=120))
        seed = draw(st_.integers(min_value=0, max_value=2**31 - 1))
        n_ins = draw(st_.integers(min_value=0, max_value=10))
        n_tdel = draw(st_.integers(min_value=0, max_value=4))
        levels = draw(st_.integers(min_value=1, max_value=16))
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        w = rng.random(m, dtype=np.float32) * 0.98 + 0.01
        return preprocess(src, dst, w, n), seed, n_ins, n_tdel, levels

    @settings(max_examples=20, deadline=None)
    @given(cases())
    def inner(case):
        g, seed, n_ins, n_tdel, levels = case
        state = _solve(g)
        rng = np.random.default_rng(seed ^ 0x5EED)
        batch = _random_batch(rng, state, n_ins=n_ins,
                              n_tree_del=n_tdel, n_rand_del=2)
        _check_update(state, batch,
                      params=GHSParams(update_levels=levels),
                      ctx=(seed, n_ins, n_tdel, levels))

    inner()


# ---------------------------------------------------------------------------
# Shard sweep (subprocess: device count locks at jax init)
# 3 shard counts × 3 kinds × 8 chained batches = 72 randomized batches
# ---------------------------------------------------------------------------

def test_apply_updates_1_2_4_shards_identical():
    out = run_child("""
import numpy as np, json
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.incremental import EdgeBatch, apply_edge_batch, apply_updates
from repro.core.mst_api import incremental_forest, minimum_spanning_forest
from repro.core.params import GHSParams

def random_batch(rng, state):
    g = state.graph
    n = g.num_vertices
    ins = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
            float(rng.random() * 0.98 + 0.01))
           for _ in range(int(rng.integers(0, 7)))]
    dels = []
    tree = np.flatnonzero(state.forest.edge_mask)
    if tree.size:
        for i in rng.choice(tree, size=min(2, tree.size), replace=False):
            dels.append((int(g.src[i]), int(g.dst[i])))
    return EdgeBatch.make(ins, dels)

params = GHSParams(update_levels=4, partitioner="hashed")
rows = []
for shards in (1, 2, 4):
    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    for ki, kind in enumerate(("rmat", "grid", "chain")):
        rng = np.random.default_rng(shards * 100 + ki)
        g = generators.generate(kind, 6, seed=7)
        state, _ = incremental_forest(g, params=params, mesh=mesh)
        ok = True
        for step in range(8):
            batch = random_batch(rng, state)
            state, st = apply_updates(state, batch, params=params,
                                      mesh=mesh)
            want = kruskal_ref.kruskal(
                state.graph)  # state.graph IS the merged graph
            ok = ok and bool(np.array_equal(
                state.forest.edge_mask, want.edge_mask))
            ok = ok and st.host_syncs == st.intervals + st.extra_syncs
        # one sharded from-scratch re-solve of the final graph
        plain, _ = minimum_spanning_forest(state.graph, mesh=mesh,
                                           params=params)
        ok = ok and bool(np.array_equal(
            state.forest.edge_mask, plain.edge_mask))
        rows.append(dict(shards=shards, kind=kind, ok=ok))
print(json.dumps(rows))
""", devices=4)
    rows = json.loads(out.strip().splitlines()[-1])
    assert len(rows) == 9
    bad = [r for r in rows if not r["ok"]]
    assert not bad, bad
