"""Property-based invariants of the MST engines (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.graph import preprocess
from repro.core.kruskal_ref import kruskal
from repro.core.mst_api import minimum_spanning_forest


@st.composite
def graphs(draw):
    n = draw(st.integers(min_value=2, max_value=48))
    m = draw(st.integers(min_value=0, max_value=160))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.random(m, dtype=np.float32) * 0.98 + 0.01
    return preprocess(src, dst, w, n)


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_boruvka_forest_invariants(g):
    want = kruskal(g)
    got, _ = minimum_spanning_forest(g, method="boruvka")
    # exact forest equality under the shared total order
    assert np.array_equal(got.edge_mask, want.edge_mask)
    # structural invariants
    assert got.num_tree_edges == g.num_vertices - got.num_components
    assert got.total_weight <= float(g.weight.sum()) + 1e-5


@settings(max_examples=8, deadline=None)
@given(graphs())
def test_ghs_forest_invariants(g):
    want = kruskal(g)
    got, _ = minimum_spanning_forest(g, method="ghs")
    assert np.array_equal(got.edge_mask, want.edge_mask)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=2, max_value=24),
       st.integers(min_value=0, max_value=200))
def test_preprocess_keeps_min_weight_duplicate(seed, n, m):
    """§3.1 dedup property: for every surviving canonical pair, the kept
    weight is the MINIMUM over all raw samples of that pair (in either
    direction); self-loops vanish; pairs are unique and sorted."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    # few distinct weights over few vertices → dense duplicate collisions
    w = rng.choice(np.asarray([0.125, 0.25, 0.5, 0.75], np.float32), m)
    g = preprocess(src, dst, w, n)
    want = {}
    for a, b, ww in zip(src, dst, w):
        if a == b:
            continue
        pair = (min(a, b), max(a, b))
        want[pair] = min(want.get(pair, np.float32(np.inf)), ww)
    got = {(int(u), int(v)): ww
           for u, v, ww in zip(g.src, g.dst, g.weight)}
    assert got == want
    pid = (g.src.astype(np.uint64) << np.uint64(32)) | g.dst.astype(np.uint64)
    assert np.all(np.diff(pid.astype(np.int64)) > 0)   # sorted, unique


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=2, max_value=40))
def test_packed_key_order(seed, n):
    """Packed uint64 keys sort exactly like (weight, edge_id) tuples."""
    from repro.core import keys
    rng = np.random.default_rng(seed)
    w = rng.random(n, dtype=np.float32)
    eid = rng.permutation(n).astype(np.uint32)
    packed = keys.pack_keys_np(w, eid)
    order_packed = np.argsort(packed, kind="stable")
    order_tuple = np.lexsort((eid, w))
    assert np.array_equal(order_packed, order_tuple)
    assert np.array_equal(keys.unpack_weight_np(packed), w)
    assert np.array_equal(keys.unpack_edge_id_np(packed), eid)
