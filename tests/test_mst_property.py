"""Property-based invariants of the MST engines (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.graph import preprocess
from repro.core.kruskal_ref import kruskal
from repro.core.mst_api import minimum_spanning_forest


@st.composite
def graphs(draw):
    n = draw(st.integers(min_value=2, max_value=48))
    m = draw(st.integers(min_value=0, max_value=160))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.random(m, dtype=np.float32) * 0.98 + 0.01
    return preprocess(src, dst, w, n)


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_boruvka_forest_invariants(g):
    want = kruskal(g)
    got, _ = minimum_spanning_forest(g, method="boruvka")
    # exact forest equality under the shared total order
    assert np.array_equal(got.edge_mask, want.edge_mask)
    # structural invariants
    assert got.num_tree_edges == g.num_vertices - got.num_components
    assert got.total_weight <= float(g.weight.sum()) + 1e-5


@settings(max_examples=8, deadline=None)
@given(graphs())
def test_ghs_forest_invariants(g):
    want = kruskal(g)
    got, _ = minimum_spanning_forest(g, method="ghs")
    assert np.array_equal(got.edge_mask, want.edge_mask)


# ---------------------------------------------------------------------------
# Metamorphic invariants (DESIGN.md §8 correctness suite)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=2, max_value=40),
       st.integers(min_value=0, max_value=160))
def test_forest_weight_invariant_under_edge_permutation(seed, n, m):
    """Permuting the RAW sample order changes nothing the solver can see:
    the preprocessed canonical graph is pid-sorted, so the forest's weight
    multiset, tree size, and component count are invariant (under ties the
    chosen pairs may differ between two valid MSTs, but by the matroid
    exchange property the sorted weight sequence cannot)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    # coarse weights → dense ties, the hard case for this invariant
    w = rng.choice(np.asarray([0.125, 0.25, 0.5, 0.75], np.float32), m)
    perm = rng.permutation(m)
    g1 = preprocess(src, dst, w, n)
    g2 = preprocess(src[perm], dst[perm], w[perm], n)
    r1, _ = minimum_spanning_forest(g1, method="boruvka")
    r2, _ = minimum_spanning_forest(g2, method="boruvka")
    assert r1.num_components == r2.num_components
    assert r1.num_tree_edges == r2.num_tree_edges
    assert np.array_equal(
        np.sort(g1.weight[r1.edge_mask].view(np.uint32)),
        np.sort(g2.weight[r2.edge_mask].view(np.uint32)))


@settings(max_examples=20, deadline=None)
@given(graphs(), st.sampled_from([np.float32(0.5), np.float32(0.25)]))
def test_forest_invariant_under_monotone_weight_remap(g, factor):
    """Scaling every weight by an exact power of two is strictly monotone
    and injective on float32, so the elected edge SET is bit-identical
    (same packed-key order, same tie-breaks)."""
    from repro.core.graph import Graph
    g2 = Graph(num_vertices=g.num_vertices, src=g.src, dst=g.dst,
               weight=(g.weight * factor).astype(np.float32))
    r1, _ = minimum_spanning_forest(g, method="boruvka")
    r2, _ = minimum_spanning_forest(g2, method="boruvka")
    assert np.array_equal(r1.edge_mask, r2.edge_mask)


@settings(max_examples=15, deadline=None)
@given(graphs(),
       st.sampled_from(["block", "hashed", "balanced"]),
       st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=4))
def test_forest_invariant_under_vertex_relabeling(g, part_name, seed, shards):
    """Vertex relabeling composed with a partitioner relabeling preserves
    canonical edge ids (partition.relabel_graph contract), so the forest —
    recorded BY canonical id — is bit-identical however vertices are
    renamed."""
    from repro.core.partition import get_partitioner, relabel_graph
    perm_part = get_partitioner(part_name).vertex_perm(g, shards)
    rng = np.random.default_rng(seed)
    perm_rand = rng.permutation(g.num_vertices)
    relabeled = relabel_graph(relabel_graph(g, perm_part), perm_rand)
    r1, _ = minimum_spanning_forest(g, method="boruvka")
    r2, _ = minimum_spanning_forest(relabeled, method="boruvka")
    assert np.array_equal(r1.edge_mask, r2.edge_mask)
    assert r1.num_components == r2.num_components
    assert r1.total_weight == r2.total_weight


@settings(max_examples=10, deadline=None)
@given(st.lists(graphs(), min_size=1, max_size=5))
def test_batched_solve_matches_singles(gs):
    """Any mix of property-generated graphs solves identically batched or
    one at a time (DESIGN.md §8 bit-identity contract)."""
    from repro.core.mst_api import minimum_spanning_forests
    batched, stats = minimum_spanning_forests(gs)
    assert len(stats.rounds_per_graph) == len(gs)
    for i, (g, got) in enumerate(zip(gs, batched)):
        single, st_single = minimum_spanning_forest(g, method="boruvka")
        assert np.array_equal(got.edge_mask, single.edge_mask), i
        assert stats.rounds_per_graph[i] == st_single.rounds, i


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=2, max_value=24),
       st.integers(min_value=0, max_value=200))
def test_preprocess_keeps_min_weight_duplicate(seed, n, m):
    """§3.1 dedup property: for every surviving canonical pair, the kept
    weight is the MINIMUM over all raw samples of that pair (in either
    direction); self-loops vanish; pairs are unique and sorted."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    # few distinct weights over few vertices → dense duplicate collisions
    w = rng.choice(np.asarray([0.125, 0.25, 0.5, 0.75], np.float32), m)
    g = preprocess(src, dst, w, n)
    want = {}
    for a, b, ww in zip(src, dst, w):
        if a == b:
            continue
        pair = (min(a, b), max(a, b))
        want[pair] = min(want.get(pair, np.float32(np.inf)), ww)
    got = {(int(u), int(v)): ww
           for u, v, ww in zip(g.src, g.dst, g.weight)}
    assert got == want
    pid = (g.src.astype(np.uint64) << np.uint64(32)) | g.dst.astype(np.uint64)
    assert np.all(np.diff(pid.astype(np.int64)) > 0)   # sorted, unique


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=2, max_value=40))
def test_packed_key_order(seed, n):
    """Packed uint64 keys sort exactly like (weight, edge_id) tuples."""
    from repro.core import keys
    rng = np.random.default_rng(seed)
    w = rng.random(n, dtype=np.float32)
    eid = rng.permutation(n).astype(np.uint32)
    packed = keys.pack_keys_np(w, eid)
    order_packed = np.argsort(packed, kind="stable")
    order_tuple = np.lexsort((eid, w))
    assert np.array_equal(order_packed, order_tuple)
    assert np.array_equal(keys.unpack_weight_np(packed), w)
    assert np.array_equal(keys.unpack_edge_id_np(packed), eid)
