"""Paper Table 2 — strong scaling over nodes, 3 graph classes.

Runs the optimized distributed engine over 1/2/4/8 shard_map shards (forced
host devices in a subprocess, since the device count is locked at jax init).
CAVEAT printed with the results: this container has ONE physical core, so
shards time-slice — wall-clock cannot show real speedup.  The scale-relevant
observables reported instead: per-shard edge work (the quantity that strong-
scales), rounds (constant in P), and collective volume per round.
Paper reference points (RMAT-24, MVS-10P): 1→63.3s, 32 nodes→2.04s (31x).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = r"""
import json, sys, time

# Pin backend + forced device count BEFORE anything touches jax
# (repro.platform raises if jax already initialized — DESIGN.md §9).
kind, scale, shards = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
from repro import platform
platform.pin(platform="cpu", host_devices=shards)

import numpy as np
from repro.compat import make_mesh
from repro.core import generators
from repro.core.boruvka_dist import minimum_spanning_forest
from repro.core.params import GHSParams

mesh = None
if shards > 1:
    mesh = make_mesh((shards,), ("x",))
g = generators.generate(kind, scale, seed=1)
# warmup (compile)
minimum_spanning_forest(g, mesh=mesh)
t0 = time.perf_counter()
res, stats = minimum_spanning_forest(g, mesh=mesh)
dt = time.perf_counter() - t0
print(json.dumps(dict(
    kind=kind, shards=shards, seconds=dt, rounds=stats.rounds,
    edges_scanned=stats.edges_scanned,
    edges_per_shard=stats.edges_scanned // shards,
    weight=res.total_weight)))
"""


def run_cell(kind: str, scale: int, shards: int) -> dict:
    # The child pins its own backend/device count via repro.platform; a
    # stray XLA_FLAGS from the caller's environment would fight it.
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, kind, str(scale), str(shards)],
        capture_output=True, text=True, env=env, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(scale: int = 13, shard_counts=(1, 2, 4, 8)):
    print(f"# Table2 — strong scaling, optimized engine, SCALE={scale}")
    print("# (1-core container: wall time is a proxy; per-shard work is "
          "the scaling observable)")
    print(f"{'graph':8s} {'P':>3s} {'time_s':>8s} {'rounds':>7s} "
          f"{'edges/shard':>12s} {'work_scaling':>12s}")
    rows = []
    for kind in ("rmat", "ssca2", "random"):
        base = None
        for p in shard_counts:
            r = run_cell(kind, scale, p)
            base = base or r["edges_per_shard"]
            ws = base / r["edges_per_shard"]
            print(f"{kind:8s} {p:3d} {r['seconds']:8.2f} {r['rounds']:7d} "
                  f"{r['edges_per_shard']:12d} {ws:11.2f}x")
            rows.append(dict(r, work_scaling=ws))
    return rows


if __name__ == "__main__":
    main()
