"""Incremental-update benchmark: apply_updates vs a from-scratch re-solve.

The serving claim of DESIGN.md §13: for a small edit batch on a large
solved graph, the cycle/cut probe certifies most edges out of the final
solve, so applying the batch is far cheaper than re-solving the merged
graph — the acceptance bar is ≥ 5x update-batch throughput at rmat
scale 14.

Each timed step draws one randomized batch (inserts + tree deletes +
arbitrary deletes), then measures BOTH paths on the SAME batch:

* ``update``  — ``mst_api.apply_updates`` (merge + probe + candidate
  solve, one fused mask readback);
* ``resolve`` — ``apply_edge_batch`` + a full ``boruvka`` solve of the
  merged graph (what a server without the incremental pass would run).

The two paths' forests are compared bit-exact on every step (the
re-solve IS the bit-identity reference), and the final state is checked
against the Kruskal oracle.  The evolving state advances with the update
path, so every step sees a realistically mutated graph.

Emits / merges into ``BENCH_incremental.json`` (``--out``).

Usage:
    PYTHONPATH=src python benchmarks/bench_incremental.py
    PYTHONPATH=src python benchmarks/bench_incremental.py --scale 12
    PYTHONPATH=src python benchmarks/bench_incremental.py --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time

from common import pin_backend


def _random_batch(rng, state, n_ins: int, n_tree_del: int, n_rand_del: int):
    import numpy as np
    from repro.core.incremental import EdgeBatch

    g = state.graph
    n = g.num_vertices
    ins = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
            float(rng.random() * 0.98 + 0.01)) for _ in range(n_ins)]
    dels = []
    tree = np.flatnonzero(state.forest.edge_mask)
    if tree.size and n_tree_del:
        for i in rng.choice(tree, size=min(n_tree_del, tree.size),
                            replace=False):
            dels.append((int(g.src[i]), int(g.dst[i])))
    dels += [(int(rng.integers(0, n)), int(rng.integers(0, n)))
             for _ in range(n_rand_del)]
    return EdgeBatch.make(ins, dels)


def bench_updates(scale: int, steps: int, batch_inserts: int,
                  levels: int, seed: int) -> dict:
    import numpy as np
    from repro.core import generators, kruskal_ref
    from repro.core.incremental import apply_edge_batch
    from repro.core.mst_api import (apply_updates, incremental_forest,
                                    minimum_spanning_forest)
    from repro.core.params import GHSParams

    params = GHSParams(update_levels=levels)
    g = generators.generate("rmat", scale, seed=seed)
    rng = np.random.default_rng(seed + 1)

    t0 = time.perf_counter()
    state, _ = incremental_forest(g, params=params)
    initial_solve_s = time.perf_counter() - t0

    # Warm both paths through a few UNTIMED stream steps: the engine's
    # pow2 compaction ladder compiles one executable per newly-seen block
    # size, and successive batches touch slightly different ladders, so a
    # single warm call is not enough to reach steady state.
    warm_steps = 3
    for _ in range(warm_steps):
        warm = _random_batch(rng, state, batch_inserts, 2, 2)
        state, _ = apply_updates(state, warm, params=params)
        minimum_spanning_forest(apply_edge_batch(state.graph, warm),
                                params=params)

    rows = []
    for step in range(steps):
        batch = _random_batch(rng, state, batch_inserts, 2, 2)

        t0 = time.perf_counter()
        new_state, st = apply_updates(state, batch, params=params)
        update_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        g2 = apply_edge_batch(state.graph, batch)
        plain, _ = minimum_spanning_forest(g2, params=params)
        resolve_s = time.perf_counter() - t0

        assert np.array_equal(new_state.forest.edge_mask,
                              plain.edge_mask), f"step {step} diverged"
        rows.append(dict(
            step=step, update_seconds=update_s, resolve_seconds=resolve_s,
            speedup=resolve_s / update_s,
            updates_applied=st.updates_applied,
            replacement_probes=st.replacement_probes,
            candidate_count=st.candidate_count,
            edges_filtered=st.edges_filtered,
            host_syncs=st.host_syncs))
        state = new_state
        print(f"  step {step}: update {update_s * 1e3:7.1f}ms  "
              f"resolve {resolve_s * 1e3:7.1f}ms  "
              f"-> {rows[-1]['speedup']:5.2f}x  "
              f"candidates {st.candidate_count}/{state.graph.num_edges}")

    want = kruskal_ref.kruskal(state.graph)
    assert np.array_equal(state.forest.edge_mask, want.edge_mask), \
        "final state diverged from the Kruskal oracle"

    upd = float(np.mean([r["update_seconds"] for r in rows]))
    res = float(np.mean([r["resolve_seconds"] for r in rows]))
    return dict(
        kind="rmat", scale=scale, seed=seed,
        num_vertices=state.graph.num_vertices,
        num_edges=state.graph.num_edges,
        batch_size=batch_inserts + 4, steps=steps,
        update_levels=levels,
        initial_solve_seconds=initial_solve_s,
        mean_update_seconds=upd, mean_resolve_seconds=res,
        update_batches_per_second=1.0 / upd,
        speedup=res / upd,
        mean_candidates=float(np.mean([r["candidate_count"]
                                       for r in rows])),
        oracle_exact=True, per_step=rows)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=14,
                    help="rmat scale of the evolving graph")
    ap.add_argument("--steps", type=int, default=8,
                    help="timed update batches")
    ap.add_argument("--batch-inserts", type=int, default=48,
                    help="inserted edges per batch (plus 2 tree deletes "
                         "and 2 arbitrary deletes)")
    ap.add_argument("--levels", type=int, default=16,
                    help="update_levels of the cycle probe (16 balances "
                         "probe cost against candidate count on rmat)")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: rmat scale 10, 3 batches, oracle-exact")
    ap.add_argument("--out", default="BENCH_incremental.json")
    args = ap.parse_args(argv)

    pin_backend("cpu")

    record = {}
    if os.path.exists(args.out):
        with open(args.out) as fh:
            record = json.load(fh)

    if args.smoke:
        print("# incremental smoke — rmat scale 10, 3 update batches")
        record["smoke"] = bench_updates(10, 3, 16, args.levels, args.seed)
        r = record["smoke"]
        print(f"  mean update {r['mean_update_seconds'] * 1e3:.1f}ms  "
              f"resolve {r['mean_resolve_seconds'] * 1e3:.1f}ms  "
              f"-> {r['speedup']:.2f}x (exact)")
    else:
        print(f"# incremental updates — rmat scale {args.scale}, "
              f"{args.steps} batches of "
              f"{args.batch_inserts}+4 edits")
        record["updates"] = bench_updates(
            args.scale, args.steps, args.batch_inserts, args.levels,
            args.seed)
        r = record["updates"]
        print(f"  mean update {r['mean_update_seconds'] * 1e3:.1f}ms  "
              f"resolve {r['mean_resolve_seconds'] * 1e3:.1f}ms  "
              f"-> {r['speedup']:.2f}x  "
              f"({r['update_batches_per_second']:.1f} batches/s)")

    with open(args.out, "w") as fh:
        json.dump(record, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
