"""Graph-pipeline benchmark: host numpy build vs the device-resident
pipeline, end-to-end (build + solve) wall time + host syncs (DESIGN.md §7).

The *host pipeline* is the historical path: numpy counter-based generation
+ ``np.lexsort`` §3.1 preprocessing on host, then the engine pads and
uploads the edge arrays.  The *device pipeline* generates, preprocesses,
and shards the same graph entirely on device (``repro.core.pipeline``) and
hands :class:`DeviceEdges` straight to the Borůvka engine — its only build
sync is the deduped-edge-count scalar.  Both paths are byte-identical by
construction (asserted per run), so the speedup is pure pipeline, not a
different graph.

Also sweeps 1/2/4/8 shard_map shards at the same scale (subprocesses with
forced host devices) and checks every partitioner (block/hashed/balanced)
stays bit-identical to the numpy Borůvka oracle.

Emits ``BENCH_graph_pipeline.json`` (or ``--out``).

Usage:
    PYTHONPATH=src python benchmarks/bench_graph_pipeline.py --scale 14
    PYTHONPATH=src python benchmarks/bench_graph_pipeline.py \
        --scale 10 --repeats 1 --shards 1,2      # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_SWEEP_CHILD = r"""
import json, sys, time
import numpy as np
from repro.compat import make_mesh
from repro.core import kruskal_ref, pipeline
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams
from repro.core.pipeline import GraphSpec

kind, scale, shards, repeats = (sys.argv[1], int(sys.argv[2]),
                                int(sys.argv[3]), int(sys.argv[4]))
mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
spec = GraphSpec(kind, scale, seed=1)


def best(fn, *a, **kw):
    out, t = None, float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*a, **kw)
        t = min(t, time.perf_counter() - t0)
    return out, t


host_graph, t_host_build = best(pipeline.build_host, spec)
want = kruskal_ref.boruvka_numpy(host_graph)

pipeline.build(spec, mesh=mesh)                      # compile warm-up
dev, t_dev_build = best(pipeline.build, spec, mesh=mesh)
byte_identical = bool(
    np.array_equal(host_graph.src, dev.to_graph().src)
    and np.array_equal(host_graph.dst, dev.to_graph().dst)
    and np.array_equal(host_graph.weight.view(np.uint32),
                       dev.to_graph().weight.view(np.uint32)))

# Engine warm-up for BOTH input shapes: the host pad (pow2 >= deduped m)
# and the pipeline capacity (pow2 >= raw samples) can compile different
# executables, and --repeats 1 cannot amortize a cold compile.
minimum_spanning_forest(host_graph, mesh=mesh)
minimum_spanning_forest(dev, mesh=mesh)
(res_h, st_h), t_host_solve = best(
    minimum_spanning_forest, host_graph, mesh=mesh)
(res_d, st_d), t_dev_solve = best(minimum_spanning_forest, dev, mesh=mesh)

row = dict(
    kind=kind, scale=scale, shards=shards,
    num_edges=host_graph.num_edges,
    byte_identical=byte_identical,
    host=dict(build_s=t_host_build, solve_s=t_host_solve,
              total_s=t_host_build + t_host_solve,
              build_syncs=0, solve_syncs=st_h.host_syncs,
              oracle_exact=bool(np.array_equal(res_h.edge_mask,
                                               want.edge_mask))),
    device=dict(build_s=t_dev_build, solve_s=t_dev_solve,
                total_s=t_dev_build + t_dev_solve,
                build_syncs=1, solve_syncs=st_d.host_syncs,
                oracle_exact=bool(np.array_equal(res_d.edge_mask,
                                                 want.edge_mask))),
)
row["build_speedup"] = t_host_build / max(t_dev_build, 1e-9)
row["end_to_end_speedup"] = row["host"]["total_s"] / row["device"]["total_s"]

partitioners = {}
for part in ("block", "hashed", "balanced"):
    got, _ = minimum_spanning_forest(
        host_graph, mesh=mesh, params=GHSParams(partitioner=part))
    partitioners[part] = bool(np.array_equal(got.edge_mask, want.edge_mask))
row["partitioners_exact"] = partitioners
print(json.dumps(row))
"""


def run_shard(kind: str, scale: int, shards: int, repeats: int) -> dict:
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={shards}",
        PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SWEEP_CHILD, kind, str(scale), str(shards),
         str(repeats)],
        capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--kind", default="rmat")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--shards", default="1,2,4,8",
                    help="comma-separated shard counts")
    ap.add_argument("--out", default="BENCH_graph_pipeline.json")
    args = ap.parse_args(argv)

    shard_counts = [int(s) for s in args.shards.split(",") if s]
    rows = []
    print(f"# graph-pipeline bench — {args.kind} scale {args.scale}")
    print(f"{'shards':>6s} {'host_build':>11s} {'dev_build':>10s} "
          f"{'host_e2e':>9s} {'dev_e2e':>8s} {'build_x':>8s} {'e2e_x':>6s} "
          f"{'bytes==':>7s}")
    for p in shard_counts:
        row = run_shard(args.kind, args.scale, p, args.repeats)
        rows.append(row)
        h, d = row["host"], row["device"]
        print(f"{p:6d} {h['build_s']:11.3f} {d['build_s']:10.3f} "
              f"{h['total_s']:9.3f} {d['total_s']:8.3f} "
              f"{row['build_speedup']:8.2f} {row['end_to_end_speedup']:6.2f} "
              f"{str(row['byte_identical']):>7s}")

    bad = [r for r in rows
           if not (r["byte_identical"] and r["host"]["oracle_exact"]
                   and r["device"]["oracle_exact"]
                   and all(r["partitioners_exact"].values()))]
    print(f"# {len(rows)} shard configs, {len(rows) - len(bad)} fully "
          f"byte-identical + oracle-exact (all partitioners)")
    for r in bad:
        print("  MISMATCH:", r)

    record = dict(
        kind=args.kind, scale=args.scale, repeats=args.repeats,
        rows=rows,
        all_ok=not bad,
        end_to_end_speedup_1shard=rows[0]["end_to_end_speedup"] if rows
        else None,
    )
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")
    if bad:
        raise SystemExit("graph-pipeline identity sweep failed")
    return record


if __name__ == "__main__":
    main()
