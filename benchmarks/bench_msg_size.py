"""Paper Fig 4 — aggregated message size over execution intervals.

Runs the faithful engine on 4 shards (forced host devices, subprocess) and
reports the average interconnect bytes per superstep across 10 equal
intervals — reproducing the paper's observation that aggregated messages
shrink as the run progresses (fragments merge → less traffic), which is why
it concludes short-message latency/injection-rate becomes the limit.

The per-superstep series comes from the engine's on-device history buffers
(DESIGN.md §6): collecting it no longer forces a host sync per superstep —
the device-resident loop still reads back one scalar vector per
``check_frequency`` interval and the histories ride the final state fetch.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = r"""
import json, sys

# Pin backend + forced device count BEFORE anything touches jax
# (repro.platform raises if jax already initialized).
kind, scale, shards = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
from repro import platform
platform.pin(platform="cpu", host_devices=shards)

import numpy as np
from repro.core import generators
from repro.core.ghs_message import minimum_spanning_forest
from repro.core.params import GHSParams
from repro.compat import make_mesh

mesh = make_mesh((shards,), ("x",))
g = generators.generate(kind, scale, seed=1)
res, st = minimum_spanning_forest(g, mesh=mesh, collect_history=True)
by = np.asarray(st.bytes_history, np.float64)      # cumulative remote bytes
per_step = np.diff(np.concatenate([[0.0], by]))
n = len(per_step)
k = 10
bounds = np.linspace(0, n, k + 1).astype(int)
intervals = [float(per_step[a:b].mean()) if b > a else 0.0
             for a, b in zip(bounds[:-1], bounds[1:])]
print(json.dumps(dict(supersteps=n, intervals=intervals,
                      total_remote_msgs=st.sent_remote,
                      host_syncs=st.host_syncs,
                      loop_intervals=st.intervals)))
"""


def main(scale: int = 9, shards: int = 4):
    # The child pins its own backend/device count via repro.platform; a
    # stray XLA_FLAGS from the caller's environment would fight it.
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, "rmat", str(scale), str(shards)],
        capture_output=True, text=True, env=env, check=True)
    r = json.loads(out.stdout.strip().splitlines()[-1])
    print(f"# Fig4 — avg remote bytes/superstep over 10 intervals "
          f"(RMAT-{scale}, {shards} shards, faithful engine)")
    for i, v in enumerate(r["intervals"]):
        bar = "#" * max(1, int(v / (max(r['intervals']) + 1e-9) * 40))
        print(f"interval {i}: {v:10.0f} B  {bar}")
    print(f"supersteps={r['supersteps']} "
          f"remote_msgs={r['total_remote_msgs']} "
          f"host_syncs={r['host_syncs']} "
          f"(history via on-device buffers: "
          f"{r['host_syncs'] / max(r['supersteps'], 1):.2f} syncs/superstep)")
    return r


if __name__ == "__main__":
    main()
