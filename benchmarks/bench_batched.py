"""Batched multi-graph benchmark: vmapped bucket dispatch vs looped solves.

The serving workload (DESIGN.md §8): many small/medium MST queries whose
per-invocation dispatch + sync overhead dominates algorithmic work.  A mixed
batch of rmat graphs (scales cycling over ``--scales``) is solved two ways:

* **loop**    — one ``minimum_spanning_forest`` engine invocation per graph
  (the fused single-graph device loop; this is already the PR-1 fast path).
* **batched** — ``minimum_spanning_forests``: graphs bucketed by padded
  shape, each bucket's round loop advanced under ``jax.vmap`` with ONE
  dispatch and ONE scalar readback per interval for the whole bucket.

Every batched forest is checked bit-identical to its single-graph solve and
edge-set-exact against the Kruskal oracle, per run.  Emits
``BENCH_batched.json``.

Usage:
    PYTHONPATH=src python benchmarks/bench_batched.py
    PYTHONPATH=src python benchmarks/bench_batched.py \
        --batch 8 --repeats 1          # CI smoke
"""
from __future__ import annotations

import argparse
import json
import time


def build_graphs(scales, batch: int):
    from repro.core import generators
    return [
        generators.generate("rmat", scales[i % len(scales)], seed=100 + i)
        for i in range(batch)
    ]


def run_loop(graphs, params):
    from repro.core.mst_api import minimum_spanning_forest
    results, syncs = [], 0
    for g in graphs:
        res, st = minimum_spanning_forest(
            g, method="boruvka", params=params)
        results.append(res)
        syncs += st.host_syncs
    return results, syncs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scales", default="8,9,10",
                    help="comma-separated rmat scales cycled over the batch")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_batched.json")
    args = ap.parse_args(argv)

    import numpy as np
    from repro.core import kruskal_ref
    from repro.core.mst_api import minimum_spanning_forests
    from repro.core.params import GHSParams

    scales = [int(s) for s in args.scales.split(",") if s]
    graphs = build_graphs(scales, args.batch)
    params = GHSParams()

    # Warm both paths (compile caches) before timing.
    loop_results, _ = run_loop(graphs, params)
    batched_results, warm_stats = minimum_spanning_forests(
        graphs, params=params)

    best_loop, loop_syncs = float("inf"), 0
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        loop_results, loop_syncs = run_loop(graphs, params)
        best_loop = min(best_loop, time.perf_counter() - t0)

    best_batch, stats = float("inf"), warm_stats
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        batched_results, stats = minimum_spanning_forests(
            graphs, params=params)
        best_batch = min(best_batch, time.perf_counter() - t0)

    # Correctness gate: bit-identical to single solves AND oracle-exact.
    bit_identical = oracle_exact = True
    for g, single, batched in zip(graphs, loop_results, batched_results):
        want = kruskal_ref.kruskal(g)
        bit_identical &= bool(
            np.array_equal(batched.edge_mask, single.edge_mask)
            and batched.total_weight == single.total_weight)
        oracle_exact &= bool(
            np.array_equal(batched.edge_mask, want.edge_mask)
            and batched.num_components == want.num_components)

    n_graphs = len(graphs)
    record = dict(
        batch=n_graphs,
        scales=scales,
        num_edges_total=int(sum(g.num_edges for g in graphs)),
        loop=dict(seconds=best_loop,
                  graphs_per_s=n_graphs / best_loop,
                  host_syncs=loop_syncs),
        batched=dict(seconds=best_batch,
                     graphs_per_s=n_graphs / best_batch,
                     host_syncs=stats.host_syncs,
                     intervals=stats.intervals,
                     buckets=stats.buckets,
                     bucket_shapes=[list(s) for s in stats.bucket_shapes],
                     compactions=stats.compactions),
        speedup=best_loop / best_batch,
        all_bit_identical=bit_identical,
        oracle_exact=oracle_exact,
    )
    # Sync contract: per bucket, one readback per interval + one final fetch.
    record["batched"]["syncs_per_interval"] = (
        (stats.host_syncs - stats.buckets) / max(stats.intervals, 1))

    print(f"# batched bench — rmat scales {scales}, batch {n_graphs}, "
          f"{record['num_edges_total']} edges total")
    print(f"{'path':8s} {'time_s':>8s} {'graphs/s':>9s} {'syncs':>6s}")
    print(f"{'loop':8s} {best_loop:8.3f} "
          f"{record['loop']['graphs_per_s']:9.1f} {loop_syncs:6d}")
    print(f"{'batched':8s} {best_batch:8.3f} "
          f"{record['batched']['graphs_per_s']:9.1f} "
          f"{stats.host_syncs:6d}")
    print(f"speedup: {record['speedup']:.2f}x   buckets: {stats.buckets}   "
          f"bit-identical: {bit_identical}   oracle-exact: {oracle_exact}")

    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")
    if not (bit_identical and oracle_exact):
        raise SystemExit("batched forests diverged")
    return record


if __name__ == "__main__":
    main()
