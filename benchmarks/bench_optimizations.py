"""Paper Fig 2 — impact of optimizations, base → final.

Ladder (paper §3.3-3.5): base (linear search, strict order, uncompressed)
→ binary search → hashing → + relaxed Test queue → + message compression
(final).  Primary wall-clock is the single-core CPU proxy; the
hardware-independent counters (messages popped, re-processing share,
interconnect bytes) are what the optimizations actually move and are
reported alongside (paper: hashing −18% node time, Test queue 2× scaling,
compression −50%).
"""
from __future__ import annotations

import time

from repro.core import generators
from repro.core.ghs_message import minimum_spanning_forest
from repro.core.params import GHSParams

LADDER = [
    ("base(linear,strict,raw)", GHSParams(
        use_hashing=False, relaxed_test_queue=False,
        compress_messages=False)),
    ("+binary-search", GHSParams(
        use_hashing=False, hash_table_factor=-1.0,
        relaxed_test_queue=False, compress_messages=False)),
    ("+hashing", GHSParams(
        use_hashing=True, relaxed_test_queue=False,
        compress_messages=False)),
    ("+test-queue", GHSParams(
        use_hashing=True, relaxed_test_queue=True, check_frequency=1,
        compress_messages=False)),
    ("final(+compression)", GHSParams(
        use_hashing=True, relaxed_test_queue=True, check_frequency=1,
        compress_messages=True)),
    # Beyond-paper rung: the same final variant under the device-resident
    # superstep loop with a real interval (supersteps batch per dispatch).
    ("+device-loop(check=5)", GHSParams(
        use_hashing=True, relaxed_test_queue=True, check_frequency=5,
        compress_messages=True, round_loop="device")),
]


def run(scale: int = 9, seed: int = 1, kind: str = "rmat"):
    g = generators.generate(kind, scale, seed=seed)
    rows = []
    for name, params in LADDER:
        t0 = time.perf_counter()
        res, stats = minimum_spanning_forest(g, params=params)
        dt = time.perf_counter() - t0
        reproc = 1.0 - stats.productive / max(stats.processed, 1)
        rows.append(dict(
            name=name, seconds=dt, supersteps=stats.supersteps,
            processed=stats.processed, reprocessed_frac=reproc,
            bytes_per_msg=(5 if params.compress_messages else 8) * 4,
            host_syncs=stats.host_syncs,
            total_weight=res.total_weight))
    return rows


def main(scale: int = 9):
    rows = run(scale)
    base = rows[0]["seconds"]
    print("# Fig2 — optimization ladder "
          f"(RMAT-{scale}, faithful GHS engine, CPU proxy)")
    print(f"{'variant':26s} {'time_s':>8s} {'vs_base':>8s} {'steps':>6s} "
          f"{'popped':>9s} {'reproc%':>8s} {'B/msg':>6s} {'syncs':>6s}")
    for r in rows:
        print(f"{r['name']:26s} {r['seconds']:8.2f} "
              f"{base / r['seconds']:7.2f}x {r['supersteps']:6d} "
              f"{r['processed']:9d} {100 * r['reprocessed_frac']:7.1f}% "
              f"{r['bytes_per_msg']:6d} {r['host_syncs']:6d}")
    return rows


if __name__ == "__main__":
    main()
