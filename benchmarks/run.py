"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV at the end.
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

from benchmarks import (bench_loggops, bench_msg_size,  # noqa: E402
                        bench_optimizations, bench_profiling, bench_scaling,
                        bench_weak_scaling)
from benchmarks.common import csv_line  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller scales (CI-sized)")
    args = ap.parse_args()
    fast = args.fast
    csv = []

    t0 = time.perf_counter()
    rows = bench_optimizations.main(scale=8 if fast else 9)
    base, final = rows[0], rows[-1]
    csv.append(csv_line("fig2_optimizations", 1e6 * (time.perf_counter() - t0),
                        f"base={base['seconds']:.2f}s "
                        f"final={final['seconds']:.2f}s "
                        f"speedup={base['seconds'] / final['seconds']:.2f}x"))
    print()

    t0 = time.perf_counter()
    rows = bench_profiling.main(scale=8 if fast else 9)
    csv.append(csv_line("fig3_profiling", 1e6 * (time.perf_counter() - t0),
                        f"reproc_final="
                        f"{1 - rows[-1]['productive'] / rows[-1]['processed']:.2f}"))
    print()

    t0 = time.perf_counter()
    rows = bench_scaling.main(scale=11 if fast else 13,
                              shard_counts=(1, 2, 4) if fast else (1, 2, 4, 8))
    ws = rows[-1]["work_scaling"]
    csv.append(csv_line("table2_scaling", 1e6 * (time.perf_counter() - t0),
                        f"work_scaling_P{rows[-1]['shards']}={ws:.2f}x"))
    print()

    t0 = time.perf_counter()
    r = bench_msg_size.main(scale=8 if fast else 9, shards=4)
    first = r["intervals"][0] + 1e-9
    csv.append(csv_line("fig4_msg_size", 1e6 * (time.perf_counter() - t0),
                        f"last/first={r['intervals'][-1] / first:.2f}"))
    print()

    t0 = time.perf_counter()
    rows = bench_weak_scaling.main(
        scales=(9, 10, 11) if fast else (10, 11, 12, 13))
    csv.append(csv_line("fig5_weak_scaling", 1e6 * (time.perf_counter() - t0),
                        f"Medges/s@max={rows[-1]['meps']:.2f}"))
    print()

    t0 = time.perf_counter()
    bench_loggops.main()
    csv.append(csv_line("loggops_model", 1e6 * (time.perf_counter() - t0),
                        "paper-sec5-future-work"))
    print()

    print("=" * 24, "ROOFLINE (single-pod, from dry-run artifacts)",
          "=" * 12)
    try:
        from benchmarks import roofline
        import sys as _sys
        argv = _sys.argv
        _sys.argv = ["roofline"]
        roofline.main()
        _sys.argv = ["roofline", "--mesh", "multipod2x16x16"]
        print()
        print("=" * 24, "ROOFLINE (multi-pod 2x16x16)", "=" * 29)
        roofline.main()
        _sys.argv = argv
    except Exception as e:  # noqa: BLE001 — artifacts may be absent in CI
        print(f"(roofline skipped: {e})")
    print()

    print("name,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
