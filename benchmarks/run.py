"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV at the end.
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

from benchmarks import (bench_loggops, bench_msg_size,  # noqa: E402
                        bench_optimizations, bench_profiling, bench_scaling)
from benchmarks.common import csv_line  # noqa: E402


def _run_weak_scaling(fast: bool) -> dict:
    """Run bench_weak_scaling in a child (it pins 16 forced host devices,
    which is only legal before jax initializes) and load its JSON."""
    import json
    import os
    import subprocess
    import tempfile

    script = os.path.join(os.path.dirname(__file__), "bench_weak_scaling.py")
    out = os.path.join(tempfile.mkdtemp(prefix="weak_scaling_"),
                       "BENCH_weak_scaling.json")
    argv = [sys.executable, script, "--out", out]
    if fast:
        argv.append("--smoke")
    subprocess.run(argv, check=True)
    with open(out) as f:
        return json.load(f)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller scales (CI-sized)")
    args = ap.parse_args()
    fast = args.fast
    csv = []

    t0 = time.perf_counter()
    rows = bench_optimizations.main(scale=8 if fast else 9)
    base, final = rows[0], rows[-1]
    csv.append(csv_line("fig2_optimizations", 1e6 * (time.perf_counter() - t0),
                        f"base={base['seconds']:.2f}s "
                        f"final={final['seconds']:.2f}s "
                        f"speedup={base['seconds'] / final['seconds']:.2f}x"))
    print()

    t0 = time.perf_counter()
    rows = bench_profiling.main(scale=8 if fast else 9)
    csv.append(csv_line("fig3_profiling", 1e6 * (time.perf_counter() - t0),
                        f"reproc_final="
                        f"{1 - rows[-1]['productive'] / rows[-1]['processed']:.2f}"))
    print()

    t0 = time.perf_counter()
    rows = bench_scaling.main(scale=11 if fast else 13,
                              shard_counts=(1, 2, 4) if fast else (1, 2, 4, 8))
    ws = rows[-1]["work_scaling"]
    csv.append(csv_line("table2_scaling", 1e6 * (time.perf_counter() - t0),
                        f"work_scaling_P{rows[-1]['shards']}={ws:.2f}x"))
    print()

    t0 = time.perf_counter()
    r = bench_msg_size.main(scale=8 if fast else 9, shards=4)
    first = r["intervals"][0] + 1e-9
    csv.append(csv_line("fig4_msg_size", 1e6 * (time.perf_counter() - t0),
                        f"last/first={r['intervals'][-1] / first:.2f}"))
    print()

    # Weak scaling pins 16 forced host devices, which must happen before
    # jax initializes — by this point the in-process backend is up, so the
    # leg runs as a subprocess and its JSON record is read back.
    t0 = time.perf_counter()
    ws = _run_weak_scaling(fast)
    last = ws["rows"][-1]
    comp = last["boruvka_compressed"]
    csv.append(csv_line(
        "fig5_weak_scaling", 1e6 * (time.perf_counter() - t0),
        f"P{last['shards']} Medges/s/shard={comp['meps_per_shard']:.2f} "
        f"host_syncs={comp['host_syncs']} intervals={comp['intervals']} "
        f"wire_drop>r1={last['comm']['reduction_beyond_round1']:.1f}x"))
    print()

    t0 = time.perf_counter()
    bench_loggops.main()
    csv.append(csv_line("loggops_model", 1e6 * (time.perf_counter() - t0),
                        "paper-sec5-future-work"))
    print()

    print("=" * 24, "ROOFLINE (single-pod, from dry-run artifacts)",
          "=" * 12)
    try:
        from benchmarks import roofline
        import sys as _sys
        argv = _sys.argv
        _sys.argv = ["roofline"]
        roofline.main()
        _sys.argv = ["roofline", "--mesh", "multipod2x16x16"]
        print()
        print("=" * 24, "ROOFLINE (multi-pod 2x16x16)", "=" * 29)
        roofline.main()
        _sys.argv = argv
    except Exception as e:  # noqa: BLE001 — artifacts may be absent in CI
        print(f"(roofline skipped: {e})")
    print()

    print("name,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
