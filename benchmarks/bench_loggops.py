"""LogGP-style analytic model — the paper's §5 *planned future work*,
delivered: "we plan to study the main limiting factors of the algorithm
using LogGOPS model".

Method: measure the faithful engine's message ledger at small SCALEs, fit
    messages(N, M) = a · N·log2(N) + b · M
(GHS bound: 5N log N + 2M), extrapolate to the paper's RMAT-24, and predict
node-count scaling on an FDR-Infiniband LogGP parameterization:

    T(P) = o·msgs/P  +  G·bytes(P)/P  +  L·supersteps(P)  +  c·work/P

Validation target: the paper's own Table 2 (RMAT-24: 63.3 s on 1 node →
2.04 s on 32).  The model's job is the SHAPE (where scaling saturates and
why) — its conclusion matches the paper's: past ~32 nodes per-message
overhead (o·msgs/P flattens into L·supersteps, which does NOT shrink with
P) becomes the limit, i.e. "latency or injection rate of short messages".
"""
from __future__ import annotations

import numpy as np

from repro.core import generators
from repro.core.ghs_message import minimum_spanning_forest
from repro.core.params import GHSParams

# LogGP-ish constants for FDR IB + Xeon E5-2690 (paper's MVS-10P).
L = 1.3e-6          # network latency, s
O_MSG = 60e-9       # per-message CPU overhead (pack/unpack/dispatch), s
G_BYTE = 1 / 5.8e9  # s per byte (FDR ~56 Gb/s effective)
C_WORK = 9e-9       # s per message-processing step on the host CPU


def measure(scales=(7, 8, 9)):
    rows = []
    for sc in scales:
        g = generators.generate("rmat", sc, seed=1)
        _, st = minimum_spanning_forest(
            g, params=GHSParams(check_frequency=1))
        msgs = st.sent_local + st.sent_remote
        rows.append(dict(scale=sc, n=g.num_vertices, m=g.num_edges,
                         msgs=msgs, processed=st.processed,
                         supersteps=st.supersteps))
    return rows


def fit(rows):
    """Constrained fit: b=2 fixed by GHS theory (≤2 Test/Reject per edge),
    a free — the unconstrained 2-param fit is ill-conditioned at small
    scales where N·log2N ≈ M."""
    b = 2.0
    a_vals = [(r["msgs"] - b * r["m"]) / (r["n"] * np.log2(r["n"]))
              for r in rows]
    return (float(np.mean(a_vals)), b)


def predict_table2(coef, scale=24, avg_degree=32,
                   nodes=(1, 2, 4, 8, 16, 32, 64), procs_per_node=8,
                   bytes_per_msg=20):
    n = 1 << scale
    m = n * avg_degree // 2
    msgs = coef[0] * n * np.log2(n) + coef[1] * m
    work = 1.35 * msgs          # measured reprocessing factor ≈ 1.2-1.5
    print(f"# LogGP prediction, RMAT-{scale}: fitted msgs = "
          f"{coef[0]:.2f}·N·log2N + {coef[1]:.2f}·M = {msgs:.3e}")
    print(f"{'nodes':>6s} {'pred_s':>8s} {'scaling':>8s}   paper_Table2")
    paper = {1: 63.27, 2: 36.12, 4: 17.98, 8: 8.47, 16: 5.41, 32: 2.04,
             64: 1.45}
    base = None
    rows = []
    for p_nodes in nodes:
        p = p_nodes * procs_per_node
        remote_frac = 1 - 1 / p                # block-random destinations
        # supersteps ≈ levels × per-level waves; grows slowly with P
        supersteps = 60 * np.log2(n) / 24 * (1 + 0.1 * np.log2(p))
        t = (O_MSG * msgs / p
             + G_BYTE * bytes_per_msg * msgs * remote_frac / p
             + L * msgs * remote_frac / (p * 64)   # aggregated: /MAX batch
             + L * supersteps * np.log2(max(p, 2))  # sync/allreduce waves
             + C_WORK * work / p)
        base = base or t
        rows.append((p_nodes, t, base / t, paper.get(p_nodes)))
        pt = paper.get(p_nodes)
        print(f"{p_nodes:6d} {t:8.2f} {base / t:7.2f}x   "
              f"{pt if pt is not None else 'n/a'}")
    return rows


def main():
    rows = measure()
    for r in rows:
        print(f"measured RMAT-{r['scale']}: msgs={r['msgs']} "
              f"(N·log2N={r['n'] * int(np.log2(r['n']))}, M={r['m']}) "
              f"supersteps={r['supersteps']}")
    coef = fit(rows)
    out = predict_table2(coef)
    print("""
# Reading: magnitude and near-linear regime match Table 2; the model's
# classic LogGP terms (o, G, L) CANNOT reproduce the paper's saturation at
# 64 nodes (43.6x measured vs ~62x modeled) — independent support for the
# paper's conjecture that short-message INJECTION RATE, a term outside
# bandwidth/latency models, is the limiting factor. The beyond-paper
# synchronous engine removes that term entirely (O(log N) fused collectives).
""")
    return out


if __name__ == "__main__":
    main()
