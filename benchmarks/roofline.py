"""§Roofline — three-term analysis from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.roofline [--dir benchmarks/artifacts/dryrun]

Per (arch × shape) on the single-pod mesh:
    compute    = jaxpr_FLOPs/device / 197e12           (bf16 peak)
    memory     = HBM bytes/device   / 819e9
    collective = collective bytes/device / (3 links × 50e9)

FLOPs are the scan-aware jaxpr count (includes remat recompute; XLA's own
cost_analysis undercounts loop bodies).  HBM bytes = max(XLA's fused
'bytes accessed', live-buffer floor from memory_analysis) — the fusion-naive
jaxpr byte count is also recorded as an upper bound.  Collective bytes come
from the post-SPMD HLO (output sizes of all-gather/all-reduce/…), divided
across the 3 usable ICI links of a v5e torus axis-pair.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link
LINKS = 3


def load(dir_: str, mesh_tag: str = "pod16x16"):
    recs = []
    for fn in sorted(glob.glob(os.path.join(dir_, f"*__{mesh_tag}.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def terms(rec: dict) -> dict:
    nd = rec["n_devices"]
    flops_dev = rec["jaxpr_flops_global"] / nd
    xla_bytes = max(rec.get("bytes_per_device", 0.0), 0.0)
    live_floor = rec["memory"]["peak_bytes"]
    bytes_dev = max(xla_bytes, live_floor)
    coll_dev = rec["collectives"]["total_bytes"]
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / (LINKS * LINK_BW)
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    model_dev = rec["model_flops"] / nd
    return dict(
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        dominant=dom[1], t_dominant=dom[0],
        useful_ratio=model_dev / max(flops_dev, 1.0),
        roofline_frac=t_c / max(t_c, t_m, t_x),
        flops_dev=flops_dev, bytes_dev=bytes_dev, coll_dev=coll_dev,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    print(f"# Roofline terms per (arch x shape), mesh={args.mesh}")
    print(f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'collective_s':>12s} {'dominant':>10s} {'MF/HF':>6s} "
          f"{'roofline%':>9s}")
    for r in recs:
        if r["status"] != "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} -- {r['status']}: "
                  f"{r['reason'][:60]}")
            continue
        t = terms(r)
        print(f"{r['arch']:24s} {r['shape']:12s} {t['t_compute']:10.4f} "
              f"{t['t_memory']:10.4f} {t['t_collective']:12.4f} "
              f"{t['dominant']:>10s} {t['useful_ratio']:6.2f} "
              f"{100 * t['roofline_frac']:8.1f}%")
    return recs


if __name__ == "__main__":
    main()
