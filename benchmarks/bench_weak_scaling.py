"""Weak scaling — P shards solve a graph that grows with P (paper Fig 5).

Pins 8 forced host devices ONCE through ``repro.platform`` (the backend-
pinning contract every bench leg follows), then builds 1/2/4/8-shard
meshes from that device pool in a single process — no subprocess per cell.
Each row P solves rmat ``base + log2 P`` (edges double with the shard
count, the weak-scaling regime) through the filter-Borůvka path
(``method="filter_boruvka"``, DESIGN.md §10), with the plain Borůvka
engine timed alongside for reference.

CAVEAT (printed with the results): this container has ONE physical core,
so forced host devices time-slice — wall-clock cannot show real weak
scaling.  The honest observables are edges/s per shard and the
filter's survivor counts, which determine the communicated volume.
"""
from __future__ import annotations

import argparse
import math
import time

from common import pin_backend

DEVICES = 8


def run_row(kind: str, scale: int, shards: int, rate: float) -> dict:
    import numpy as np
    from repro.compat import make_mesh
    from repro.core import generators
    from repro.core.mst_api import minimum_spanning_forest
    from repro.core.params import GHSParams

    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    g = generators.generate(kind, scale, seed=1)
    params = GHSParams(filter_sample_rate=rate)
    row = dict(shards=shards, scale=scale, num_vertices=g.num_vertices,
               num_edges=g.num_edges)
    masks = {}
    for method in ("filter_boruvka", "boruvka"):
        minimum_spanning_forest(g, method=method, params=params,
                                mesh=mesh)                 # warm / compile
        t0 = time.perf_counter()
        res, st = minimum_spanning_forest(g, method=method, params=params,
                                          mesh=mesh)
        dt = time.perf_counter() - t0
        masks[method] = res.edge_mask
        row[method] = dict(seconds=dt, meps=g.num_edges / dt / 1e6,
                           meps_per_shard=g.num_edges / dt / 1e6 / shards)
    assert np.array_equal(masks["filter_boruvka"], masks["boruvka"]), \
        (kind, scale, shards)
    fr = row["filter_boruvka"]
    row["speedup"] = row["boruvka"]["seconds"] / fr["seconds"]
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--base-scale", type=int, default=13,
                    help="shards=1 graph scale; P shards solve "
                         "base + log2 P")
    ap.add_argument("--kind", default="rmat")
    ap.add_argument("--rate", type=float, default=0.15)
    args = ap.parse_args(argv)

    pin_backend("cpu", host_devices=DEVICES)

    print(f"# weak scaling — {args.kind}, P shards solve scale "
          f"base+log2 P (base {args.base_scale}), {DEVICES} forced host "
          f"devices, filter-Borůvka vs plain")
    print("# (1-core container: shards time-slice; edges/s-per-shard is "
          "the honest observable)")
    print(f"{'P':>3s} {'scale':>6s} {'edges':>9s} {'filter_s':>9s} "
          f"{'plain_s':>8s} {'speedup':>8s} {'Meps/shard':>11s}")
    rows = []
    for shards in (1, 2, 4, 8):
        scale = args.base_scale + int(math.log2(shards))
        r = run_row(args.kind, scale, shards, args.rate)
        print(f"{shards:3d} {scale:6d} {r['num_edges']:9d} "
              f"{r['filter_boruvka']['seconds']:9.2f} "
              f"{r['boruvka']['seconds']:8.2f} {r['speedup']:7.2f}x "
              f"{r['filter_boruvka']['meps_per_shard']:11.2f}")
        rows.append(r)
    return rows


if __name__ == "__main__":
    main()
