"""Weak scaling — P shards solve a graph that grows with P (paper Fig 5).

Pins 16 forced host devices ONCE through ``repro.platform`` (the backend-
pinning contract every bench leg follows), then builds 1/2/4/8/16-shard
meshes from that device pool in a single process — no subprocess per cell.
Each row P drives FOUR paths over rmat ``base + log2 P`` (edges double
with the shard count, the weak-scaling regime):

* ``boruvka`` with ``collective="pmin"``   — dense per-round reduction;
* ``boruvka`` with ``collective="compressed"`` — the DESIGN.md §11 delta
  exchange (packed candidate ring, bit-identity fallback);
* ``filter_boruvka`` — sample→solve→filter→solve (DESIGN.md §10);
* ``ghs``            — the paper-faithful message engine (capped scale:
  its superstep count grows with diameter, so it rides a smaller graph);

plus the batched serving path (``minimum_spanning_forests``) with a batch
that grows with P.  Every row cross-checks all masks against the Kruskal
oracle, records per-row ``host_syncs`` / ``intervals`` / overlap counters
uniformly, and captures the per-ROUND collective bytes of the dense vs
compressed reduction from a ``check_frequency=1`` probe pair — the honest
"what actually crossed the wire" comparison.  Emits
``BENCH_weak_scaling.json``.

CAVEAT (printed and recorded): this container has ONE physical core, so
forced host devices time-slice — wall-clock cannot show real weak
scaling.  The honest observables are edges/s per shard, host syncs per
solve, and the on-wire byte series.

Usage:
    PYTHONPATH=src python benchmarks/bench_weak_scaling.py
    PYTHONPATH=src python benchmarks/bench_weak_scaling.py --smoke  # CI
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

from common import pin_backend

DEVICES = 16


def _stats_row(st, dt: float, num_edges: int, shards: int) -> dict:
    """The uniform per-path record: timing + the runtime sync ledger."""
    return dict(
        seconds=dt, meps=num_edges / dt / 1e6,
        meps_per_shard=num_edges / dt / 1e6 / shards,
        host_syncs=st.host_syncs, intervals=st.intervals,
        overlapped_syncs=st.overlapped_syncs,
        speculative_intervals=st.speculative_intervals,
        comm_bytes=st.comm_bytes)


def _comm_records(st) -> list:
    return [dict(mode=m, cand_cap=int(c), rounds=int(r), bytes=int(b))
            for (m, c, r, b) in st.comm_history]


def _comm_probe(g, mesh, shards: int, rate: float) -> dict:
    """Per-ROUND on-wire bytes, dense vs compressed (check_frequency=1).

    One interval per round makes each ``comm_history`` entry a single
    round, so the two series are directly comparable round-by-round.
    ``reduction_beyond_round1`` is dense-per-round divided by the
    SMALLEST compressed per-round bytes after round 1 — how far the delta
    exchange shrinks the wire once fragments start merging.
    """
    import numpy as np
    from repro.core.mst_api import minimum_spanning_forest
    from repro.core.params import GHSParams

    series = {}
    masks = {}
    for coll in ("pmin", "compressed"):
        params = GHSParams(filter_sample_rate=rate, check_frequency=1,
                           collective=coll, interval_pipeline=0)
        res, st = minimum_spanning_forest(g, method="boruvka", params=params,
                                          mesh=mesh)
        masks[coll] = np.asarray(res.edge_mask)
        series[coll] = _comm_records(st)
    if not np.array_equal(masks["pmin"], masks["compressed"]):
        raise SystemExit("comm probe: compressed forest diverged from pmin")
    dense_rows = [r for r in series["pmin"] if r["rounds"]]
    comp_rows = [r for r in series["compressed"] if r["rounds"]]
    dense_per_round = dense_rows[0]["bytes"] if dense_rows else 0
    beyond = [r["bytes"] for r in comp_rows[1:]] or [dense_per_round]
    out = dict(
        dense_per_round=[r["bytes"] for r in dense_rows],
        compressed_per_round=comp_rows,
        dense_bytes_total=sum(r["bytes"] for r in dense_rows),
        compressed_bytes_total=sum(r["bytes"] for r in comp_rows),
        reduction_beyond_round1=(
            1.0 if not dense_per_round else
            dense_per_round / min(beyond) if min(beyond) else float("inf")))
    return out


def run_row(kind: str, scale: int, shards: int, rate: float,
            ghs_scale: int, batch_scale: int) -> dict:
    import numpy as np
    from repro.compat import make_mesh
    from repro.core import generators, kruskal_ref
    from repro.core.mst_api import (minimum_spanning_forest,
                                    minimum_spanning_forests)
    from repro.core.params import GHSParams

    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    g = generators.generate(kind, scale, seed=1)
    want = kruskal_ref.kruskal(g).edge_mask
    row = dict(shards=shards, scale=scale, num_vertices=g.num_vertices,
               num_edges=g.num_edges)

    # --- the two Borůvka collectives + filter-Borůvka, timed -------------
    paths = [("boruvka_pmin", "boruvka", "pmin"),
             ("boruvka_compressed", "boruvka", "compressed"),
             ("filter_boruvka", "filter_boruvka", "compressed")]
    ok = True
    for name, method, coll in paths:
        params = GHSParams(filter_sample_rate=rate, collective=coll)
        minimum_spanning_forest(g, method=method, params=params,
                                mesh=mesh)                 # warm / compile
        t0 = time.perf_counter()
        res, st = minimum_spanning_forest(g, method=method, params=params,
                                          mesh=mesh)
        dt = time.perf_counter() - t0
        ok &= bool(np.array_equal(np.asarray(res.edge_mask), want))
        row[name] = _stats_row(st, dt, g.num_edges, shards)
        if name.startswith("boruvka"):
            row[name]["rounds"] = st.rounds
            row[name]["comm_history"] = _comm_records(st)

    # --- per-round wire bytes, dense vs compressed -----------------------
    row["comm"] = _comm_probe(g, mesh, shards, rate)

    # --- GHS message engine (capped scale: supersteps ~ diameter) --------
    gg = g if ghs_scale == scale else generators.generate(kind, ghs_scale,
                                                          seed=1)
    ghs_want = (want if gg is g
                else kruskal_ref.kruskal(gg).edge_mask)
    params = GHSParams(filter_sample_rate=rate)
    minimum_spanning_forest(gg, method="ghs", params=params, mesh=mesh)
    t0 = time.perf_counter()
    res, st = minimum_spanning_forest(gg, method="ghs", params=params,
                                      mesh=mesh)
    dt = time.perf_counter() - t0
    ok &= bool(np.array_equal(np.asarray(res.edge_mask), ghs_want))
    row["ghs"] = _stats_row(st, dt, gg.num_edges, shards)
    row["ghs"].update(scale=ghs_scale, supersteps=st.supersteps)

    # --- batched serving path: batch grows with P ------------------------
    graphs = [generators.generate(kind, batch_scale, seed=s)
              for s in range(1, shards + 1)]
    minimum_spanning_forests(graphs)                       # warm / compile
    t0 = time.perf_counter()
    forests, bst = minimum_spanning_forests(graphs)
    dt = time.perf_counter() - t0
    for bg, f in zip(graphs, forests):
        ok &= bool(np.array_equal(np.asarray(f.edge_mask),
                                  kruskal_ref.kruskal(bg).edge_mask))
    edges = sum(bg.num_edges for bg in graphs)
    row["batched"] = _stats_row(bst, dt, edges, shards)
    row["batched"].update(batch=len(graphs), batch_scale=batch_scale,
                          graphs_per_s=len(graphs) / dt)

    row["all_bit_identical"] = ok
    if not ok:
        raise SystemExit(f"weak scaling row diverged from Kruskal: "
                         f"{kind} scale={scale} shards={shards}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--base-scale", type=int, default=10,
                    help="shards=1 graph scale; P shards solve "
                         "base + log2 P")
    ap.add_argument("--kind", default="rmat")
    ap.add_argument("--rate", type=float, default=0.15)
    ap.add_argument("--shards", default="1,2,4,8,16",
                    help="comma-separated shard counts (each <= 16)")
    ap.add_argument("--ghs-max-scale", type=int, default=8,
                    help="cap the GHS row scale (the message engine's "
                         "superstep count grows with graph diameter, and "
                         "time-sliced shards pay it per superstep)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: base scale 7, shards 1,8,16")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_weak_scaling.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        args.base_scale = min(args.base_scale, 7)
        args.shards = "1,8,16"
        args.ghs_max_scale = min(args.ghs_max_scale, 6)
    shard_counts = tuple(int(s) for s in args.shards.split(","))

    pin_backend("cpu", host_devices=DEVICES)

    caveat = ("1-core container: forced host devices time-slice; edges/s "
              "per shard, host syncs and on-wire bytes are the honest "
              "observables")
    print(f"# weak scaling — {args.kind}, P shards solve scale "
          f"base+log2 P (base {args.base_scale}), {DEVICES} forced host "
          f"devices; boruvka pmin vs compressed, filter, ghs, batched")
    print(f"# ({caveat})")
    print(f"{'P':>3s} {'scale':>6s} {'edges':>9s} {'plain_s':>8s} "
          f"{'comp_s':>7s} {'filter_s':>9s} {'ghs_s':>6s} {'batch_s':>8s} "
          f"{'syncs':>6s} {'wire_dense':>11s} {'wire_comp':>10s} "
          f"{'drop>r1':>8s}")
    rows = []
    for shards in shard_counts:
        scale = args.base_scale + int(math.log2(shards))
        r = run_row(args.kind, scale, shards, args.rate,
                    min(scale, args.ghs_max_scale),
                    max(args.base_scale - 3, 4))
        c = r["comm"]
        print(f"{shards:3d} {scale:6d} {r['num_edges']:9d} "
              f"{r['boruvka_pmin']['seconds']:8.2f} "
              f"{r['boruvka_compressed']['seconds']:7.2f} "
              f"{r['filter_boruvka']['seconds']:9.2f} "
              f"{r['ghs']['seconds']:6.2f} {r['batched']['seconds']:8.2f} "
              f"{r['boruvka_compressed']['host_syncs']:6d} "
              f"{c['dense_bytes_total']:11d} "
              f"{c['compressed_bytes_total']:10d} "
              f"{c['reduction_beyond_round1']:7.1f}x")
        rows.append(r)

    record = dict(kind=args.kind, base_scale=args.base_scale,
                  devices=DEVICES, rate=args.rate,
                  shard_counts=list(shard_counts),
                  ghs_max_scale=args.ghs_max_scale,
                  caveat=caveat, rows=rows,
                  all_bit_identical=all(r["all_bit_identical"]
                                        for r in rows))
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {out}")
    return record


if __name__ == "__main__":
    main()
