"""Paper Fig 5 — execution time for graphs of different sizes (weak scaling
by SCALE at fixed shard count; paper: RMAT-25..29 on 32 nodes)."""
from __future__ import annotations

import time

from repro.core import generators
from repro.core.boruvka_dist import minimum_spanning_forest


def main(scales=(10, 11, 12, 13, 14), kind: str = "rmat"):
    print(f"# Fig5 — time vs SCALE ({kind}, optimized engine, in-memory)")
    print(f"{'scale':>6s} {'vertices':>10s} {'edges':>10s} {'time_s':>8s} "
          f"{'Medges/s':>9s} {'rounds':>7s}")
    rows = []
    for sc in scales:
        g = generators.generate(kind, sc, seed=1)
        minimum_spanning_forest(g)                    # warm compile
        t0 = time.perf_counter()
        res, stats = minimum_spanning_forest(g)
        dt = time.perf_counter() - t0
        meps = g.num_edges / dt / 1e6
        print(f"{sc:6d} {g.num_vertices:10d} {g.num_edges:10d} "
              f"{dt:8.2f} {meps:9.2f} {stats.rounds:7d}")
        rows.append(dict(scale=sc, seconds=dt, edges=g.num_edges,
                         meps=meps))
    return rows


if __name__ == "__main__":
    main()
