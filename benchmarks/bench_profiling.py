"""Paper Fig 3 — where the time goes.

The paper profiles queue processing vs the rest and shows repeated message
processing dominating; the hardware-independent analogue here is the message
ledger: productive vs re-processed pops, Test-vs-main queue shares, and
local-vs-remote traffic, for the hash-only variant vs the final version.
"""
from __future__ import annotations

import time

from repro.core import generators
from repro.core.ghs_message import minimum_spanning_forest
from repro.core.params import GHSParams

VARIANTS = [
    ("hash-only(strict)", GHSParams(use_hashing=True,
                                    relaxed_test_queue=False)),
    ("final(relaxed)", GHSParams(use_hashing=True, relaxed_test_queue=True,
                                 check_frequency=1)),
    # Same algorithm, legacy per-superstep driver: the message ledger is
    # identical; only the host-sync column (and wall time) moves.
    ("final(host-loop)", GHSParams(use_hashing=True, relaxed_test_queue=True,
                                   check_frequency=1, round_loop="host")),
]


def main(scale: int = 9):
    g = generators.generate("rmat", scale, seed=1)
    print(f"# Fig3 — message-processing profile (RMAT-{scale})")
    print(f"{'variant':22s} {'time_s':>8s} {'popped':>9s} {'productive':>10s} "
          f"{'reproc%':>8s} {'local':>9s} {'remote':>8s} {'syncs':>6s}")
    rows = []
    for name, params in VARIANTS:
        t0 = time.perf_counter()
        _, st = minimum_spanning_forest(g, params=params)
        dt = time.perf_counter() - t0
        reproc = 100 * (1 - st.productive / max(st.processed, 1))
        print(f"{name:22s} {dt:8.2f} {st.processed:9d} {st.productive:10d} "
              f"{reproc:7.1f}% {st.sent_local:9d} {st.sent_remote:8d} "
              f"{st.host_syncs:6d}")
        rows.append(dict(name=name, seconds=dt, processed=st.processed,
                         productive=st.productive,
                         host_syncs=st.host_syncs))
    return rows


if __name__ == "__main__":
    main()
