"""Election-kernel benchmark: fused round body vs the XLA scatter chain.

Measures the Borůvka device loop with ``round_kernel="pallas"`` (fused
masked min-plus election + n-scale recording/hooking + fused shortcut —
DESIGN.md §9) against ``round_kernel="xla"`` (the per-edge scatter/gather
chain), per round and end-to-end, on the same graph.  Both paths must stay
bit-identical to the Kruskal oracle — here, across a 1/2/4-shard subprocess
sweep, and on the batched path — so the speedup can never be bought with a
different forest.

A separate small-scale leg drives the actual Pallas kernels in interpret
mode (``use_pallas=True``): interpret mode is a semantics check, not a perf
path, so it is reported informationally and only its correctness is
asserted.

Emits ``BENCH_election_kernel.json`` next to the repo root (or ``--out``).

Usage:
    PYTHONPATH=src python benchmarks/bench_election_kernel.py --scale 13
    PYTHONPATH=src python benchmarks/bench_election_kernel.py --scale 10 \
        --repeats 1 --shards 1,2 --kernel-scale 8     # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from common import pin_backend

_SWEEP_CHILD = r"""
import json, sys
import numpy as np
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.boruvka_dist import minimum_spanning_forest
from repro.core.params import GHSParams

kind, scale, shards = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
g = generators.generate(kind, scale, seed=1)
want = kruskal_ref.kruskal(g)
rows, masks = [], {}
for rk in ("xla", "pallas"):
    res, st = minimum_spanning_forest(
        g, params=GHSParams(round_kernel=rk), mesh=mesh)
    masks[rk] = res.edge_mask
    rows.append(dict(
        kind=kind, shards=shards, round_kernel=rk,
        ok=bool(np.array_equal(res.edge_mask, want.edge_mask)
                and res.total_weight == want.total_weight),
        total_weight=res.total_weight, rounds=st.rounds,
        host_syncs=st.host_syncs))
for r in rows:
    r["kernels_agree"] = bool(np.array_equal(masks["xla"], masks["pallas"]))
print(json.dumps(rows))
"""


def _time_engine(g, params, repeats: int):
    from repro.core.boruvka_dist import minimum_spanning_forest
    minimum_spanning_forest(g, params=params)            # warm / compile
    best, res, st = float("inf"), None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res, st = minimum_spanning_forest(g, params=params)
        best = min(best, time.perf_counter() - t0)
    return res, st, best


def bench_single_shard(kind: str, scale: int, repeats: int) -> dict:
    import numpy as np
    from repro.core import generators, kruskal_ref
    from repro.core.params import GHSParams

    g = generators.generate(kind, scale, seed=1)
    want = kruskal_ref.kruskal(g)
    out = dict(kind=kind, scale=scale, num_vertices=g.num_vertices,
               num_edges=g.num_edges)
    masks = {}
    for rk in ("xla", "pallas"):
        res, st, dt = _time_engine(
            g, GHSParams(round_kernel=rk), repeats)
        ok = bool(np.array_equal(res.edge_mask, want.edge_mask)
                  and res.total_weight == want.total_weight)
        masks[rk] = res.edge_mask
        out[rk] = dict(
            seconds=dt, rounds=st.rounds, host_syncs=st.host_syncs,
            intervals=st.intervals, compactions=st.compactions,
            ms_per_round=1e3 * dt / max(st.rounds, 1),
            oracle_exact=ok)
        assert ok, f"round_kernel={rk} diverged from the Kruskal oracle"
    assert bool(np.array_equal(masks["xla"], masks["pallas"])), \
        "round kernels disagree"
    out["speedup"] = out["xla"]["seconds"] / out["pallas"]["seconds"]
    out["speedup_per_round"] = (out["xla"]["ms_per_round"]
                                / out["pallas"]["ms_per_round"])
    return out


def bench_kernel_interpret(kind: str, scale: int, repeats: int) -> dict:
    """Drive the actual Pallas kernels (interpret mode) on a small graph.

    Semantics leg: asserts the kernel lowering's forest is oracle-exact;
    its timing is reported but interpret mode is NOT a perf path."""
    import numpy as np
    from repro.core import generators, kruskal_ref
    from repro.core.params import GHSParams

    g = generators.generate(kind, scale, seed=1)
    want = kruskal_ref.kruskal(g)
    res, st, dt = _time_engine(
        g, GHSParams(round_kernel="pallas", use_pallas=True), repeats)
    ok = bool(np.array_equal(res.edge_mask, want.edge_mask))
    assert ok, "Pallas interpret round kernel diverged from the oracle"
    return dict(kind=kind, scale=scale, num_edges=g.num_edges,
                seconds=dt, rounds=st.rounds,
                ms_per_round=1e3 * dt / max(st.rounds, 1),
                oracle_exact=ok, interpret=True)


def bench_batched(scale: int, count: int, repeats: int) -> dict:
    import numpy as np
    from repro.core import generators, kruskal_ref
    from repro.core.mst_api import minimum_spanning_forests
    from repro.core.params import GHSParams

    gs = [generators.generate("rmat", scale, seed=s) for s in range(count)]
    oracle = [kruskal_ref.kruskal(g) for g in gs]
    out = dict(scale=scale, count=count)
    masks = {}
    for rk in ("xla", "pallas"):
        params = GHSParams(round_kernel=rk)
        minimum_spanning_forests(gs, params=params)      # warm / compile
        best, res = float("inf"), None
        for _ in range(repeats):
            t0 = time.perf_counter()
            res, st = minimum_spanning_forests(gs, params=params)
            best = min(best, time.perf_counter() - t0)
        ok = all(np.array_equal(r.edge_mask, o.edge_mask)
                 for r, o in zip(res, oracle))
        masks[rk] = [r.edge_mask for r in res]
        out[rk] = dict(seconds=best, oracle_exact=bool(ok))
        assert ok, f"batched round_kernel={rk} diverged from the oracle"
    agree = all(np.array_equal(a, b)
                for a, b in zip(masks["xla"], masks["pallas"]))
    assert agree, "batched round kernels disagree"
    out["kernels_agree"] = bool(agree)
    out["speedup"] = out["xla"]["seconds"] / out["pallas"]["seconds"]
    return out


def bench_shard_sweep(scale: int, shard_counts, kinds) -> list[dict]:
    rows = []
    for kind in kinds:
        for p in shard_counts:
            env = dict(
                os.environ,
                XLA_FLAGS=f"--xla_force_host_platform_device_count={p}",
                PYTHONPATH="src")
            out = subprocess.run(
                [sys.executable, "-c", _SWEEP_CHILD, kind, str(scale),
                 str(p)],
                capture_output=True, text=True, env=env, check=True)
            rows.extend(json.loads(out.stdout.strip().splitlines()[-1]))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--kind", default="rmat")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--shards", default="1,2,4",
                    help="comma-separated shard counts for the sweep")
    ap.add_argument("--sweep-scale", type=int, default=None,
                    help="graph scale for the shard sweep "
                         "(default: min(scale, 11))")
    ap.add_argument("--kernel-scale", type=int, default=9,
                    help="graph scale for the Pallas-interpret leg")
    ap.add_argument("--batch-scale", type=int, default=None,
                    help="per-graph scale for the batched leg "
                         "(default: min(scale, 10))")
    ap.add_argument("--batch-count", type=int, default=8)
    ap.add_argument("--out", default="BENCH_election_kernel.json")
    args = ap.parse_args(argv)

    pin_backend("cpu")

    single = bench_single_shard(args.kind, args.scale, args.repeats)
    x, f = single["xla"], single["pallas"]
    print(f"# election-kernel bench — {args.kind} scale {args.scale}, "
          f"{single['num_edges']} edges, single shard")
    print(f"{'round_kernel':13s} {'time_s':>8s} {'ms/round':>9s} "
          f"{'rounds':>7s}")
    for name, row in (("xla", x), ("pallas(fused)", f)):
        print(f"{name:13s} {row['seconds']:8.3f} {row['ms_per_round']:9.2f} "
              f"{row['rounds']:7d}")
    print(f"speedup: {single['speedup']:.2f}x end-to-end, "
          f"{single['speedup_per_round']:.2f}x per round")

    kern = bench_kernel_interpret(args.kind, args.kernel_scale,
                                  max(args.repeats, 1))
    print(f"# Pallas interpret leg — scale {args.kernel_scale}: "
          f"oracle_exact={kern['oracle_exact']} "
          f"({kern['ms_per_round']:.1f} ms/round, semantics check only)")

    shard_counts = [int(s) for s in args.shards.split(",") if s]
    sweep_scale = args.sweep_scale or min(args.scale, 11)
    sweep = bench_shard_sweep(sweep_scale, shard_counts,
                              ("rmat", "ssca2", "random"))
    bad = [r for r in sweep
           if not (r["ok"] and r["kernels_agree"])]
    print(f"# shard sweep — scale {sweep_scale}, shards {shard_counts}: "
          f"{len(sweep)} runs, {len(sweep) - len(bad)} bit-identical to "
          f"the Kruskal oracle and across round kernels")
    for r in bad:
        print("  MISMATCH:", r)

    batch_scale = args.batch_scale or min(args.scale, 10)
    batched = bench_batched(batch_scale, args.batch_count, args.repeats)
    print(f"# batched leg — {batched['count']}x scale {batched['scale']}: "
          f"bit-identical={batched['kernels_agree']}, "
          f"speedup {batched['speedup']:.2f}x")

    record = dict(
        single_shard=single,
        kernel_interpret=kern,
        sweep=dict(scale=sweep_scale, rows=sweep,
                   all_bit_identical=not bad),
        batched=batched,
    )
    with open(args.out, "w") as fh:
        json.dump(record, fh, indent=2)
    print(f"wrote {args.out}")
    if bad:
        raise SystemExit("bit-identity sweep failed")
    return record


if __name__ == "__main__":
    main()
