"""GHS superstep-loop benchmark: host syncs + wall time, before/after.

Compares the legacy host-driven superstep loop (``round_loop="host"`` — one
dispatch and one blocking scalar readback per superstep, plus the seed
driver's per-invocation jit rebuild) against the device-resident loop
(``round_loop="device"`` — ``check_frequency`` supersteps per fused
``lax.while_loop`` dispatch, one length-3 scalar readback per interval, and
the runtime layer's compile cache).  The legacy timing deliberately includes
its per-invocation build: that is exactly how the seed driver behaved, and
the compile cache is part of what the shared runtime adds (DESIGN.md §6).

Also sweeps 1/2/4 shard_map shards × the paper graph classes in
subprocesses and checks both loops stay bit-identical to the Kruskal
oracle.

Emits ``BENCH_superstep_loop.json`` next to the repo root (or ``--out``).

Usage:
    PYTHONPATH=src python benchmarks/bench_superstep_loop.py --scale 10
    PYTHONPATH=src python benchmarks/bench_superstep_loop.py --scale 9 \
        --repeats 1 --shards 1,2 --sweep-scale 6      # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_SWEEP_CHILD = r"""
import json, sys
import numpy as np
from repro.compat import make_mesh
from repro.core import generators, kruskal_ref
from repro.core.ghs_message import minimum_spanning_forest
from repro.core.params import GHSParams

kind, scale, shards = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
g = generators.generate(kind, scale, seed=1)
want = kruskal_ref.kruskal(g)
rows = []
for loop in ("device", "host"):
    res, st = minimum_spanning_forest(
        g, params=GHSParams(round_loop=loop), mesh=mesh)
    rows.append(dict(
        kind=kind, shards=shards, round_loop=loop,
        ok=bool(np.array_equal(res.edge_mask, want.edge_mask)
                and res.total_weight == want.total_weight),
        total_weight=res.total_weight, supersteps=st.supersteps,
        intervals=st.intervals, host_syncs=st.host_syncs))
print(json.dumps(rows))
"""


def _time_engine(g, params, repeats: int):
    from repro.core.ghs_message import minimum_spanning_forest
    minimum_spanning_forest(g, params=params)   # warm the compile cache
    best, res, st = float("inf"), None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res, st = minimum_spanning_forest(g, params=params)
        best = min(best, time.perf_counter() - t0)
    return res, st, best


def bench_single_shard(kind: str, scale: int, repeats: int) -> dict:
    import numpy as np
    from repro.core import generators, kruskal_ref
    from repro.core.params import GHSParams

    g = generators.generate(kind, scale, seed=1)
    want = kruskal_ref.kruskal(g)
    out = dict(kind=kind, scale=scale, num_vertices=g.num_vertices,
               num_edges=g.num_edges,
               note=("legacy timing includes its per-invocation jit build "
                     "(seed-driver behavior); the device runtime amortizes "
                     "compiles via the shared cache"))
    for loop in ("host", "device"):
        res, st, dt = _time_engine(
            g, GHSParams(round_loop=loop), repeats)
        ok = bool(np.array_equal(res.edge_mask, want.edge_mask)
                  and res.total_weight == want.total_weight)
        out[loop] = dict(
            seconds=dt, supersteps=st.supersteps, intervals=st.intervals,
            host_syncs=st.host_syncs,
            ms_per_superstep=1e3 * dt / max(st.supersteps, 1),
            syncs_per_superstep=st.host_syncs / max(st.supersteps, 1),
            oracle_exact=ok)
        assert ok, f"{loop} loop diverged from the Kruskal oracle"
    out["speedup"] = out["host"]["seconds"] / out["device"]["seconds"]
    # Contract: the device loop syncs once per interval (+ one final fetch);
    # the legacy driver synced every superstep (two fetches before the fuse).
    dev = out["device"]
    dev["syncs_per_interval"] = (
        (dev["host_syncs"] - 1) / max(dev["intervals"], 1))
    return out


def bench_shard_sweep(scale: int, shard_counts, kinds) -> list[dict]:
    rows = []
    for kind in kinds:
        for p in shard_counts:
            env = dict(
                os.environ,
                XLA_FLAGS=f"--xla_force_host_platform_device_count={p}",
                PYTHONPATH="src")
            out = subprocess.run(
                [sys.executable, "-c", _SWEEP_CHILD, kind, str(scale),
                 str(p)],
                capture_output=True, text=True, env=env, check=True)
            rows.extend(json.loads(out.stdout.strip().splitlines()[-1]))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--kind", default="rmat")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--shards", default="1,2,4",
                    help="comma-separated shard counts for the sweep")
    ap.add_argument("--sweep-scale", type=int, default=None,
                    help="graph scale for the shard sweep "
                         "(default: min(scale, 7))")
    ap.add_argument("--out", default="BENCH_superstep_loop.json")
    args = ap.parse_args(argv)

    single = bench_single_shard(args.kind, args.scale, args.repeats)
    h, d = single["host"], single["device"]
    print(f"# superstep-loop bench — {args.kind} scale {args.scale}, "
          f"{single['num_edges']} edges, single shard, faithful GHS engine")
    print(f"{'loop':8s} {'time_s':>8s} {'ms/step':>9s} {'syncs':>6s} "
          f"{'syncs/step':>11s}")
    for name, row in (("host", h), ("device", d)):
        print(f"{name:8s} {row['seconds']:8.3f} "
              f"{row['ms_per_superstep']:9.2f} {row['host_syncs']:6d} "
              f"{row['syncs_per_superstep']:11.2f}")
    print(f"speedup: {single['speedup']:.2f}x   device syncs/interval: "
          f"{d['syncs_per_interval']:.2f}")

    shard_counts = [int(s) for s in args.shards.split(",") if s]
    sweep_scale = args.sweep_scale or min(args.scale, 7)
    sweep = bench_shard_sweep(sweep_scale, shard_counts,
                              ("rmat", "ssca2", "random"))
    bad = [r for r in sweep if not r["ok"]]
    print(f"# shard sweep — scale {sweep_scale}, shards {shard_counts}: "
          f"{len(sweep)} runs, {len(sweep) - len(bad)} bit-identical to the "
          f"Kruskal oracle")
    for r in bad:
        print("  MISMATCH:", r)

    record = dict(
        single_shard=single,
        sweep=dict(scale=sweep_scale, rows=sweep,
                   all_bit_identical=not bad),
    )
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")
    if bad:
        raise SystemExit("bit-identity sweep failed")
    return record


if __name__ == "__main__":
    main()
