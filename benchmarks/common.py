"""Shared benchmark helpers."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pin_backend(platform: str = "cpu", host_devices: int | None = None) -> None:
    """Pin the bench process's backend explicitly (repro.platform).

    Must be called before the first jax computation.  ``host_devices``
    also honors an existing ``--xla_force_host_platform_device_count`` in
    XLA_FLAGS (the shard-sweep children set it through the environment),
    so benches call this unconditionally.
    """
    from repro import platform as platform_lib
    platform_lib.pin(platform=platform, host_devices=host_devices)


def timeit(fn, *args, repeats: int = 1, **kw):
    """Returns (result, best_seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
