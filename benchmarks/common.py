"""Shared benchmark helpers."""
from __future__ import annotations

import time


def timeit(fn, *args, repeats: int = 1, **kw):
    """Returns (result, best_seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
