"""Continuous-batching serving benchmark: latency SLOs under Poisson load.

Drives the MST service (DESIGN.md §12, ``repro.launch.serve``) with an
open-loop Poisson arrival process at several offered loads.  Per load the
bench reports p50/p99 latency, achieved graphs/s, the shed rate (typed
backpressure: oversized graphs at admission, queue-full under overload),
and the flush-trigger mix (size vs deadline) — the "millions of users"
story of ROADMAP made measurable.

Every served forest is verified edge-set-exact against the Kruskal oracle
AND bit-identical to its single-graph engine solve, per run.  The bucket
lattice is warmed once up front (compiled executables live in the
process-global jit cache, so per-load services start hot — the measured
latencies are steady-state, not compile time).  Emits
``BENCH_serving.json``.

Usage:
    PYTHONPATH=src python benchmarks/bench_serving.py
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke   # CI leg
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from common import pin_backend


def build_graphs(requests: int, max_vertices: int, seed: int):
    """Mixed-size rmat request stream.  Degree 8 keeps every scale inside
    the edge capacity; every 16th graph runs full degree 32 so the
    oversize-shed path sees real traffic."""
    import numpy as np

    from repro.core import generators
    rng = np.random.default_rng(seed)
    scale_top = max(max_vertices.bit_length() - 1, 3)
    return [
        generators.generate(
            "rmat", int(rng.integers(3, scale_top + 1)),
            avg_degree=8 if i % 16 else 32,
            seed=int(rng.integers(0, 2**31)))
        for i in range(requests)
    ]


def run_load(params, graphs, rate: float, seed: int, max_rounds=None):
    import numpy as np

    from repro.core import kruskal_ref
    from repro.core.mst_api import minimum_spanning_forest
    from repro.launch.serve import MSTService, run_poisson

    service = MSTService(params, max_rounds=max_rounds)
    futures = run_poisson(service, graphs, rate=rate, seed=seed)

    oracle_exact = bit_identical = True
    for g, f in zip(graphs, futures):
        if f is None:
            continue
        res = f.result()
        want = kruskal_ref.kruskal(g)
        if not (np.array_equal(res.edge_mask, want.edge_mask)
                and res.num_components == want.num_components):
            oracle_exact = False
        single, _ = minimum_spanning_forest(g, params=params)
        if not np.array_equal(res.edge_mask, single.edge_mask):
            bit_identical = False

    s = service.stats
    return dict(
        rate=rate,
        offered=len(graphs),
        oracle_exact=oracle_exact,
        bit_identical=bit_identical,
        **s.summary(),
    )


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer requests, smaller graphs")
    ap.add_argument("--rates", default="5,15,40",
                    help="comma-separated offered loads, graphs/second")
    ap.add_argument("--requests", type=int, default=160)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--max-vertices", type=int, default=256)
    ap.add_argument("--max-edges", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rates = "10,25,50"
        args.requests = 48
        args.max_vertices = 32
        args.max_edges = 128

    pin_backend("cpu")
    from repro.core.params import GHSParams
    from repro.launch.serve import MSTService

    rates = [float(r) for r in args.rates.split(",")]
    assert len(rates) >= 3, "report at least three offered loads"
    params = GHSParams(
        serve_lanes=args.lanes,
        serve_max_wait_ms=args.max_wait_ms,
        serve_max_queue=args.max_queue,
        batch_max_vertices=args.max_vertices,
        batch_max_edges=args.max_edges)

    t0 = time.perf_counter()
    warmed = MSTService(params).warmup()
    t_warm = time.perf_counter() - t0
    print(f"warmup: {warmed} bucket shapes in {t_warm:.1f}s")

    graphs = build_graphs(args.requests, args.max_vertices, args.seed)
    rows = []
    for rate in rates:
        row = run_load(params, graphs, rate, args.seed)
        rows.append(row)
        print(f"rate {rate:>6.1f}/s: p50 {row['p50_ms']:8.1f} ms  "
              f"p99 {row['p99_ms']:8.1f} ms  "
              f"{row['graphs_per_s']:6.1f} graphs/s  "
              f"shed {row['shed_rate']:.1%}")

    rec = dict(
        config=dict(
            rates=rates, requests=args.requests, lanes=args.lanes,
            max_wait_ms=args.max_wait_ms, max_queue=args.max_queue,
            max_vertices=args.max_vertices, max_edges=args.max_edges,
            seed=args.seed, smoke=bool(args.smoke),
            params=dataclasses.asdict(params)),
        warmup=dict(buckets=warmed, seconds=round(t_warm, 2)),
        rows=rows,
        all_oracle_exact=all(r["oracle_exact"] for r in rows),
        all_bit_identical=all(r["bit_identical"] for r in rows),
    )
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {args.out}")
    assert rec["all_oracle_exact"], "a served forest diverged from Kruskal"
    assert rec["all_bit_identical"], \
        "a served forest diverged from its single-graph solve"
    return rec


if __name__ == "__main__":
    main()
