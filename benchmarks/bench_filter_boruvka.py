"""Filter-Borůvka benchmark: the sampling hybrid vs the plain engine.

Legs (``--legs``, comma-separated, default all):

* ``speedup`` — rmat scale 14, ``method="boruvka"`` vs
  ``method="filter_boruvka"`` end-to-end (warm, best-of-repeats), both
  Kruskal-exact.  The acceptance bar is ≥ 2x for the hybrid.
* ``scale``   — the scale ladder the hybrid unlocks: exact Kruskal oracle
  at 14, full independent numpy-Borůvka oracle at 16, and sampled
  spot-check certification at 18 (and 20 with ``--scale20``): the forest
  is structurally consistent, spans every component, and a few thousand
  randomly sampled non-tree edges are each certified non-MSF by the cycle
  rule (endpoints connected through strictly lighter tree edges).
* ``weak``    — one row per shard count 1/2/4/8 (8 forced host devices
  pinned once through ``repro.platform``), growing the graph with the
  shard count (scale 14 + log2 P).  CAVEAT: this container has one
  physical core, so shards time-slice; edges/s per shard is the honest
  observable, wall-clock is a proxy.

Emits / merges into ``BENCH_filter_boruvka.json`` (``--out``).

Usage:
    PYTHONPATH=src python benchmarks/bench_filter_boruvka.py
    PYTHONPATH=src python benchmarks/bench_filter_boruvka.py \
        --legs speedup,scale --max-scale 16
    PYTHONPATH=src python benchmarks/bench_filter_boruvka.py --smoke  # CI
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from common import pin_backend

_WEAK_CHILD = r"""
import json, math, sys, time
from repro import platform
platform.pin(platform="cpu", host_devices=8)
import numpy as np
from repro.compat import make_mesh
from repro.core import generators
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams

base, rate = int(sys.argv[1]), float(sys.argv[2])
rows = []
for shards in (1, 2, 4, 8):
    scale = base + int(math.log2(shards))
    mesh = make_mesh((shards,), ("x",)) if shards > 1 else None
    g = generators.generate("rmat", scale, seed=1)
    params = GHSParams(filter_sample_rate=rate)
    minimum_spanning_forest(g, method="filter_boruvka",
                            params=params, mesh=mesh)      # warm / compile
    t0 = time.perf_counter()
    res, st = minimum_spanning_forest(g, method="filter_boruvka",
                                      params=params, mesh=mesh)
    dt = time.perf_counter() - t0
    rows.append(dict(
        shards=shards, scale=scale, num_vertices=g.num_vertices,
        num_edges=g.num_edges, seconds=dt,
        meps=g.num_edges / dt / 1e6,
        meps_per_shard=g.num_edges / dt / 1e6 / shards,
        edges_filtered=st.edges_filtered, filter_passes=st.filter_passes,
        total_weight=res.total_weight))
print(json.dumps(rows))
"""


def sampled_spot_check(g, res, num_queries: int = 2048, seed: int = 0) -> dict:
    """Offline certificate sweep for scales beyond exact oracles.

    Asserts (1) the tree bitmap is a consistent forest (every union along
    ascending keys merges two components), (2) the forest spans: every
    edge's endpoints share a final component, and (3) each of
    ``num_queries`` randomly sampled non-tree edges is certified non-MSF
    by the cycle rule — its endpoints are connected through tree edges
    with strictly smaller packed keys.  Under the globally distinct
    (weight ‖ edge-id) total order these checks certify the unique MSF on
    the probed set.
    """
    import numpy as np
    from repro.core.kruskal_ref import _DSU

    keys = g.packed_keys
    tree = np.flatnonzero(res.edge_mask)
    order = tree[np.argsort(keys[tree])]

    dsu = _DSU(g.num_vertices)
    for e in order:
        assert dsu.union(int(g.src[e]), int(g.dst[e])), \
            f"tree edge {e} closes a cycle"
    comp = np.fromiter((dsu.find(v) for v in range(g.num_vertices)),
                       np.int64, g.num_vertices)
    assert bool(np.all(comp[g.src] == comp[g.dst])), "forest does not span"
    assert res.num_components == np.unique(comp).size

    nontree = np.flatnonzero(~res.edge_mask)
    rng = np.random.default_rng(seed)
    q = rng.choice(nontree, size=min(num_queries, nontree.size),
                   replace=False)
    q = q[np.argsort(keys[q])]
    sweep, ti = _DSU(g.num_vertices), 0
    for e in q:
        while ti < order.size and keys[order[ti]] < keys[e]:
            t = order[ti]
            sweep.union(int(g.src[t]), int(g.dst[t]))
            ti += 1
        assert sweep.find(int(g.src[e])) == sweep.find(int(g.dst[e])), \
            f"non-tree edge {e} lacks a lighter tree path (not cycle-max)"
    return dict(queries=int(q.size), tree_edges=int(tree.size), ok=True)


def _time_method(g, method, params, repeats: int):
    from repro.core.mst_api import minimum_spanning_forest
    minimum_spanning_forest(g, method=method, params=params)  # warm
    best, res, st = float("inf"), None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res, st = minimum_spanning_forest(g, method=method, params=params)
        best = min(best, time.perf_counter() - t0)
    return res, st, best


def bench_speedup(scale: int, repeats: int) -> dict:
    import numpy as np
    from repro.core import generators, kruskal_ref
    from repro.core.params import GHSParams

    g = generators.generate("rmat", scale, seed=1)
    want = kruskal_ref.kruskal(g)
    out = dict(kind="rmat", scale=scale, num_vertices=g.num_vertices,
               num_edges=g.num_edges)
    rows = {}
    for name, method, params in (
            ("boruvka", "boruvka", GHSParams()),
            ("filter_boruvka", "filter_boruvka", GHSParams()),
            ("filter_boruvka_pallas", "filter_boruvka",
             GHSParams(round_kernel="pallas"))):
        res, st, dt = _time_method(g, method, params, repeats)
        ok = bool(np.array_equal(res.edge_mask, want.edge_mask))
        assert ok, f"{name} diverged from the Kruskal oracle"
        rows[name] = dict(
            seconds=dt, oracle_exact=ok,
            edges_filtered=st.edges_filtered,
            filter_passes=st.filter_passes,
            host_syncs=st.host_syncs)
    out.update(rows)
    out["speedup"] = rows["boruvka"]["seconds"] \
        / rows["filter_boruvka"]["seconds"]
    out["speedup_pallas_kernel"] = rows["boruvka"]["seconds"] \
        / rows["filter_boruvka_pallas"]["seconds"]
    return out


def bench_scale_ladder(max_scale: int, repeats: int,
                       num_queries: int) -> list[dict]:
    import numpy as np
    from repro.core import generators, kruskal_ref
    from repro.core.params import GHSParams

    rows = []
    for scale in (14, 16, 18, 20):
        if scale > max_scale:
            break
        g = generators.generate("rmat", scale, seed=1)
        params = GHSParams(round_kernel="pallas")
        res, st, dt = _time_method(g, "filter_boruvka", params,
                                   repeats if scale <= 16 else 1)
        row = dict(kind="rmat", scale=scale, num_vertices=g.num_vertices,
                   num_edges=g.num_edges, seconds=dt,
                   meps=g.num_edges / dt / 1e6,
                   edges_filtered=st.edges_filtered,
                   filter_passes=st.filter_passes,
                   survivor_history=list(st.survivor_history),
                   total_weight=res.total_weight)
        if scale <= 14:
            want = kruskal_ref.kruskal(g)
            assert bool(np.array_equal(res.edge_mask, want.edge_mask))
            row["verify"] = "kruskal_exact"
        elif scale <= 16:
            want = kruskal_ref.boruvka_numpy(g)
            assert bool(np.array_equal(res.edge_mask, want.edge_mask))
            row["spot_check"] = sampled_spot_check(g, res, num_queries)
            row["verify"] = "numpy_boruvka_exact+spot_check"
        else:
            row["spot_check"] = sampled_spot_check(g, res, num_queries)
            row["verify"] = "spot_check"
        rows.append(row)
        print(f"  scale {scale}: {dt:6.2f}s  {row['meps']:6.2f} Medges/s  "
              f"filtered {st.edges_filtered}/{g.num_edges}  "
              f"[{row['verify']}]")
    return rows


def bench_weak_scaling(base_scale: int, rate: float) -> list[dict]:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)          # the child pins its own devices
    out = subprocess.run(
        [sys.executable, "-c", _WEAK_CHILD, str(base_scale), str(rate)],
        capture_output=True, text=True, env=env, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def bench_smoke(num_queries: int) -> dict:
    """CI leg: rmat scale 12, Kruskal-exact + the spot-check sweep."""
    import numpy as np
    from repro.core import generators, kruskal_ref
    from repro.core.params import GHSParams

    g = generators.generate("rmat", 12, seed=1)
    want = kruskal_ref.kruskal(g)
    res, st, dt = _time_method(g, "filter_boruvka", GHSParams(), 1)
    assert bool(np.array_equal(res.edge_mask, want.edge_mask)), \
        "filter_boruvka diverged from the Kruskal oracle"
    spot = sampled_spot_check(g, res, num_queries)
    return dict(kind="rmat", scale=12, num_edges=g.num_edges, seconds=dt,
                edges_filtered=st.edges_filtered,
                filter_passes=st.filter_passes, oracle_exact=True,
                spot_check=spot)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--legs", default="speedup,scale,weak",
                    help="comma-separated: speedup,scale,weak")
    ap.add_argument("--scale", type=int, default=14,
                    help="graph scale for the speedup leg")
    ap.add_argument("--max-scale", type=int, default=18,
                    help="top of the scale ladder (18 or 20)")
    ap.add_argument("--scale20", action="store_true",
                    help="shorthand for --max-scale 20")
    ap.add_argument("--weak-base-scale", type=int, default=14,
                    help="shards=1 scale of the weak-scaling leg "
                         "(P shards solve base + log2 P)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--queries", type=int, default=2048,
                    help="sampled non-tree edges per spot check")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: scale-12 oracle-exact spot-check leg only")
    ap.add_argument("--out", default="BENCH_filter_boruvka.json")
    args = ap.parse_args(argv)

    pin_backend("cpu")

    record = {}
    if os.path.exists(args.out):
        with open(args.out) as fh:
            record = json.load(fh)

    if args.smoke:
        print("# filter-Borůvka smoke — rmat scale 12")
        record["smoke"] = bench_smoke(args.queries)
        print(f"  exact, {record['smoke']['edges_filtered']} filtered, "
              f"{record['smoke']['spot_check']['queries']} spot checks ok")
    else:
        legs = set(args.legs.split(","))
        if "speedup" in legs:
            print(f"# speedup — rmat scale {args.scale}, "
                  f"filter_boruvka vs boruvka")
            record["speedup"] = bench_speedup(args.scale, args.repeats)
            r = record["speedup"]
            print(f"  boruvka {r['boruvka']['seconds']:.3f}s  "
                  f"filter {r['filter_boruvka']['seconds']:.3f}s  "
                  f"-> {r['speedup']:.2f}x "
                  f"({r['speedup_pallas_kernel']:.2f}x with pallas round "
                  f"kernel)")
        if "scale" in legs:
            ms = 20 if args.scale20 else args.max_scale
            print(f"# scale ladder — rmat up to {ms} "
                  f"(filter_boruvka, pallas round kernel)")
            record["scale_ladder"] = bench_scale_ladder(
                ms, args.repeats, args.queries)
        if "weak" in legs:
            print("# weak scaling — 8 forced host devices, "
                  "P shards solve rmat "
                  f"{args.weak_base_scale} + log2 P  "
                  "(1-core container: edges/s is a proxy)")
            record["weak_scaling"] = bench_weak_scaling(
                args.weak_base_scale,
                rate=0.15)
            for row in record["weak_scaling"]:
                print(f"  P={row['shards']}  scale {row['scale']}  "
                      f"{row['seconds']:6.2f}s  {row['meps']:6.2f} Medges/s"
                      f"  filtered {row['edges_filtered']}")

    with open(args.out, "w") as fh:
        json.dump(record, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
