"""Sharded, atomic, reshardable checkpointing (fault tolerance + elasticity).

Layout:  <dir>/step_<N>/  manifest.json  +  one .npy per tree leaf.
  * atomic: written to a tmp dir, fsync'd, then os.replace'd into place —
    a crash mid-save never corrupts the previous checkpoint;
  * reshard-on-restore: leaves are loaded host-side and device_put with the
    CURRENT mesh's shardings, so a job can resume on a different device
    count (elastic scaling) or topology;
  * async: saves can run on a background thread (the train loop donates a
    host snapshot and keeps going);
  * retention: keep_last prunes old steps.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.sharding.specs import tree_paths

_MANIFEST = "manifest.json"


def _leaf_file(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None,
         keep_last: int = 3) -> str:
    """Blocking save. Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    paths = tree_paths(tree)
    keys = sorted(paths)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
    try:
        meta = {"step": step, "extra": extra or {}, "leaves": []}
        for i, k in enumerate(keys):
            arr = np.asarray(paths[k])
            np.save(os.path.join(tmp, _leaf_file(i)), arr)
            meta["leaves"].append(
                {"path": k, "file": _leaf_file(i),
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(meta, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep_last)
    return final


def save_async(ckpt_dir: str, step: int, tree: Any, **kw) -> threading.Thread:
    """Snapshot to host memory now, write on a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs=kw, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``template``; device_put with
    ``shardings`` (same structure) if given — this is the elastic reshard."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        meta = json.load(f)
    by_path = {leaf["path"]: leaf for leaf in meta["leaves"]}
    tmpl_paths = tree_paths(template)
    shard_paths = tree_paths(shardings) if shardings is not None else {}
    out = {}
    for k, tv in tmpl_paths.items():
        leaf = by_path.get(k)
        if leaf is None:
            raise KeyError(f"checkpoint missing leaf {k}")
        arr = np.load(os.path.join(d, leaf["file"]))
        want_shape = tuple(getattr(tv, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {k}: ckpt {arr.shape} vs {want_shape}")
        if k in shard_paths:
            arr = jax.device_put(arr, shard_paths[k])
        out[k] = arr
    # rebuild tree with template structure
    leaves_sorted = [out[k] for k in sorted(tmpl_paths)]
    tdef = jax.tree.structure(template)
    flat_keys = sorted(tmpl_paths)
    key_order = {k: i for i, k in enumerate(flat_keys)}
    # tree_paths sorts dict keys the same way jax flattens dicts (sorted),
    # so positional rebuild is safe for dict/list/tuple trees.
    rebuilt = tdef.unflatten(
        [out[k] for k in _flatten_order(template)])
    del leaves_sorted, key_order
    return rebuilt, meta


def _flatten_order(tree) -> list:
    order = []

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}/{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}" if prefix else str(i), v)
        else:
            order.append(prefix)

    walk("", tree)
    return order


def _prune(ckpt_dir: str, keep_last: int):
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_"))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
