"""Continuous-batching MST service with latency SLOs (DESIGN.md §12).

The online half of the batched engine: DESIGN.md §8 solves a CLOSED batch
(`mst_api.minimum_spanning_forests`), this module accepts an OPEN request
stream.  Each submitted graph is routed by
:func:`repro.core.pipeline.bucket_shape` (the ``params.batch_bucket``
admission policy) into a per-shape queue; the dispatcher flushes a queue
when it reaches ``params.serve_lanes`` graphs OR when its oldest request has
waited ``params.serve_max_wait_ms`` — whichever comes first — packs it with
:func:`repro.core.pipeline.pack_bucket`, solves it through
:func:`repro.core.mst_api.solve_packed`, and completes the requests'
futures in arrival order.

Part-full flushes dispatch at the pow2-rounded OCCUPIED lane count (ghost
graphs — single vertex, no edges — pad only up to that width, capped at
``serve_lanes``): a solo deadline flush pays a width-1 solve instead of a
full-width one, which is what keeps the LOW-rate regime's mean latency near
its p50 (the fixed-width policy drove it to ~21x p50 — see BENCH_serving
history).  :meth:`MSTService.warmup` precompiles the pow2 shape lattice up
to ``batch_max_vertices`` / ``batch_max_edges`` at startup, at EVERY
adaptive flush width per shape, so no runtime flush compiles.

Update requests (DESIGN.md §13) share the same bucket/flush/backpressure
path: :meth:`MSTService.submit_update` merges the edge batch at admission
(the updated graph routes the bucket and trips the same oversize guard),
queues it under an update-kind bucket key, and the flush plans every
request's cycle/cut probe, solves all candidate subgraphs through ONE
batched ``minimum_spanning_forests`` dispatch, and completes the futures
with new :class:`~repro.core.incremental.IncrementalForest` handles —
each bit-identical to a standalone ``mst_api.apply_updates`` call.

Backpressure (PR 4's capacity guards made online): an oversized graph is
shed at submit with :class:`OversizeError`, a full bucket queue sheds with
:class:`QueueFullError` — typed, counted in :class:`ServeStats`, never a
silent drop or truncation.

Dispatch happens ONLY inside :meth:`MSTService.poll` / :meth:`drain` (never
inside ``submit``), and the service takes an injectable clock — both
deadline-flush and backpressure paths are testable deterministically, with
no sleeps in assertions.

    PYTHONPATH=src python -m repro.launch.serve --smoke

(The language-model demo driver formerly here lives in
:mod:`repro.launch.serve_lm`.)
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Callable, Optional

import numpy as np

from repro.core import incremental, mst_api, pipeline, runtime
from repro.core.graph import Graph
from repro.core.params import DEFAULT_PARAMS, GHSParams
from repro.core.partition import pow2ceil

# Trailing-window size of the ServeStats latency ledger: enough samples for
# stable p50/p99 estimates, bounded so a long-running service cannot grow
# without bound (the ``completed`` counter stays exact forever).
LATENCY_WINDOW = 4096


class ShedError(RuntimeError):
    """Base of the typed backpressure rejections (never raised itself)."""


class OversizeError(ShedError):
    """Graph exceeds ``batch_max_vertices`` / ``batch_max_edges`` — it can
    never be packed, so it is rejected at submit (PR 4's capacity guard)."""


class QueueFullError(ShedError):
    """The graph's bucket queue is at ``serve_max_queue`` — the service is
    over-rate for this shape; retry after a poll drains the queue."""


def _ghost_graph() -> Graph:
    """Inert padding lane: one vertex, zero edges — solves to an empty
    forest in round one and can never elect an edge."""
    return Graph(num_vertices=1,
                 src=np.zeros(0, np.int32),
                 dst=np.zeros(0, np.int32),
                 weight=np.zeros(0, np.float32))


@dataclasses.dataclass
class ServeStats:
    """Serving ledger (DESIGN.md §12).

    Counters: ``accepted`` / ``completed`` requests, sheds by cause
    (``shed_oversize`` at admission, ``shed_queue_full`` at the per-bucket
    bound), flushes by trigger (``size_flushes`` — a queue reached
    ``serve_lanes``; ``deadline_flushes`` — the oldest request aged past
    ``serve_max_wait_ms``; ``drain_flushes`` — explicit :meth:`drain`),
    ``ghost_lanes`` padded into part-full flushes, ``max_queue_depth``
    high-water mark across buckets, ``buckets_warmed`` executables
    precompiled at startup, and ``update_requests`` /
    ``updates_applied`` / ``replacement_probes`` metering the
    incremental-update kind (DESIGN.md §13, summed from the per-request
    :class:`~repro.core.runtime.EngineStats` ledger fields).

    ``latencies_ms`` holds one submit→complete measurement per served
    request over a TRAILING window of :data:`LATENCY_WINDOW` samples (a
    bounded deque — a long soak stays memory-flat); :meth:`percentile` /
    :meth:`summary` reduce it to the SLO numbers (p50/p99) and report
    ``latency_samples`` alongside the exact ``completed`` count, so the
    window is never mistaken for the population.  ``graphs_per_s`` is
    filled by the drivers that know wall-clock span
    (:func:`run_poisson`)."""

    accepted: int = 0
    completed: int = 0
    shed_oversize: int = 0
    shed_queue_full: int = 0
    size_flushes: int = 0
    deadline_flushes: int = 0
    drain_flushes: int = 0
    ghost_lanes: int = 0
    max_queue_depth: int = 0
    buckets_warmed: int = 0
    update_requests: int = 0
    updates_applied: int = 0
    replacement_probes: int = 0
    graphs_per_s: float = 0.0
    latencies_ms: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW))

    def record_latency(self, ms: float) -> None:
        """Append one sample; the deque evicts beyond the window."""
        self.latencies_ms.append(ms)

    @property
    def shed(self) -> int:
        return self.shed_oversize + self.shed_queue_full

    @property
    def flushes(self) -> int:
        return self.size_flushes + self.deadline_flushes \
            + self.drain_flushes

    @property
    def shed_rate(self) -> float:
        offered = self.accepted + self.shed
        return self.shed / offered if offered else 0.0

    def percentile(self, q: float) -> float:
        """Percentile over the trailing :data:`LATENCY_WINDOW` samples."""
        if not self.latencies_ms:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_ms), q))

    def summary(self) -> dict:
        return {
            "accepted": self.accepted,
            "completed": self.completed,
            "shed": self.shed,
            "shed_oversize": self.shed_oversize,
            "shed_queue_full": self.shed_queue_full,
            "shed_rate": round(self.shed_rate, 4),
            "size_flushes": self.size_flushes,
            "deadline_flushes": self.deadline_flushes,
            "drain_flushes": self.drain_flushes,
            "ghost_lanes": self.ghost_lanes,
            "max_queue_depth": self.max_queue_depth,
            "buckets_warmed": self.buckets_warmed,
            "update_requests": self.update_requests,
            "latency_samples": len(self.latencies_ms),
            "p50_ms": round(self.percentile(50), 3),
            "p99_ms": round(self.percentile(99), 3),
            "mean_ms": (round(float(np.mean(np.asarray(self.latencies_ms))),
                              3)
                        if self.latencies_ms else float("nan")),
            "graphs_per_s": round(self.graphs_per_s, 2),
        }


@dataclasses.dataclass
class _Request:
    graph: Graph            # solve kind: the input; update kind: the merged
                            # (updated) graph that routed the bucket
    future: Future
    t_submit: float
    # Update-kind payload (None on solve requests): the handle to evolve
    # and the edge batch to apply at flush time.
    forest: "Optional[incremental.IncrementalForest]" = None
    edge_batch: "Optional[incremental.EdgeBatch]" = None


class MSTService:
    """Continuous-batching MST solver: ``submit()`` graphs, ``poll()`` the
    dispatcher, read results off the returned futures.

    ``clock`` is injectable (defaults to ``time.monotonic``); tests drive
    deadline expiry by passing explicit ``now`` values to :meth:`poll`
    instead of sleeping.  Dispatch happens only in :meth:`poll` /
    :meth:`drain`, so a burst of submits between polls exercises the
    ``serve_max_queue`` backpressure bound deterministically.
    """

    def __init__(
        self,
        params: GHSParams = DEFAULT_PARAMS,
        *,
        clock: Callable[[], float] = time.monotonic,
        max_rounds: Optional[int] = None,
    ):
        if params.serve_lanes < 1:
            raise ValueError(
                f"serve_lanes must be >= 1, got {params.serve_lanes}")
        if params.serve_max_queue < params.serve_lanes:
            raise ValueError(
                f"serve_max_queue ({params.serve_max_queue}) must be >= "
                f"serve_lanes ({params.serve_lanes}); a full dispatch "
                f"could otherwise never assemble")
        self.params = params
        self.stats = ServeStats()
        self._clock = clock
        self._max_rounds = max_rounds
        # bucket shape -> FIFO of _Request; insertion-ordered so poll()
        # visits buckets in first-traffic order (deterministic).
        self._queues: "OrderedDict[tuple, deque]" = OrderedDict()

    # -- admission ---------------------------------------------------------

    def submit(self, graph, *, t_arrival: Optional[float] = None) -> Future:
        """Admit one graph; returns a future resolving to its
        :class:`~repro.core.kruskal_ref.ForestResult`.

        ``t_arrival`` optionally backdates the request to its scheduled
        arrival time (open-loop benchmarking: latency is measured from when
        the request WOULD have arrived, not from when a busy driver got
        around to submitting it).  Raises :class:`OversizeError` /
        :class:`QueueFullError` on backpressure — typed and counted, the
        request is NOT queued."""
        g = runtime.as_graph(graph)
        p = self.params
        try:
            shape = pipeline.bucket_shape(
                g.num_vertices, g.num_edges, bucket=p.batch_bucket,
                max_vertices=p.batch_max_vertices or None,
                max_edges=p.batch_max_edges or None)
        except ValueError as e:
            self.stats.shed_oversize += 1
            raise OversizeError(str(e)) from None
        q = self._queues.setdefault(shape, deque())
        if len(q) >= p.serve_max_queue:
            self.stats.shed_queue_full += 1
            raise QueueFullError(
                f"bucket {shape} queue is full "
                f"({p.serve_max_queue} pending)")
        fut: Future = Future()
        q.append(_Request(graph=g, future=fut,
                          t_submit=(self._clock() if t_arrival is None
                                    else float(t_arrival))))
        self.stats.accepted += 1
        self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                         len(q))
        return fut

    def submit_update(
        self,
        forest: "incremental.IncrementalForest",
        edge_batch: "incremental.EdgeBatch",
        *,
        t_arrival: Optional[float] = None,
    ) -> Future:
        """Admit one incremental update (DESIGN.md §13); returns a future
        resolving to the NEW :class:`~repro.core.incremental.IncrementalForest`
        handle, bit-identical to ``mst_api.apply_updates`` on the inputs.

        The edge batch is merged here (host glue) so the UPDATED graph
        routes the bucket and trips the same ``OversizeError`` guard as a
        solve; update buckets queue separately from solve buckets (an
        update-kind key) but share the size-or-deadline flush, the
        ``serve_max_queue`` bound, and the stats ledger.  Malformed
        batches (endpoints/weights out of range) raise ``ValueError`` at
        the caller — that is an input bug, not backpressure."""
        p = self.params
        g2 = incremental.apply_edge_batch(forest.graph, edge_batch)
        try:
            shape = ("update",) + pipeline.bucket_shape(
                g2.num_vertices, g2.num_edges, bucket=p.batch_bucket,
                max_vertices=p.batch_max_vertices or None,
                max_edges=p.batch_max_edges or None)
        except ValueError as e:
            self.stats.shed_oversize += 1
            raise OversizeError(str(e)) from None
        q = self._queues.setdefault(shape, deque())
        if len(q) >= p.serve_max_queue:
            self.stats.shed_queue_full += 1
            raise QueueFullError(
                f"bucket {shape} queue is full "
                f"({p.serve_max_queue} pending)")
        fut: Future = Future()
        q.append(_Request(graph=g2, future=fut,
                          t_submit=(self._clock() if t_arrival is None
                                    else float(t_arrival)),
                          forest=forest, edge_batch=edge_batch))
        self.stats.accepted += 1
        self.stats.update_requests += 1
        self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                         len(q))
        return fut

    # -- dispatch ----------------------------------------------------------

    def queue_depth(self, shape: Optional[tuple] = None) -> int:
        if shape is not None:
            return len(self._queues.get(shape, ()))
        return sum(len(q) for q in self._queues.values())

    def poll(self, now: Optional[float] = None) -> int:
        """Run the dispatcher once: flush every bucket that is full
        (``serve_lanes``) or whose oldest request has waited past
        ``serve_max_wait_ms``.  Returns the number of flushes.

        A caller-injected ``now`` (virtual clock) is threaded through to
        the flushes' completion stamps, so latency ledgers never mix
        timebases; with no injection, completion is stamped from the real
        clock AFTER the solve (the solve's own wall time counts)."""
        injected = now is not None
        if now is None:
            now = self._clock()
        p = self.params
        wait_s = p.serve_max_wait_ms / 1e3
        flushed = 0
        for shape, q in list(self._queues.items()):
            while len(q) >= p.serve_lanes:
                self.stats.size_flushes += 1
                self._flush(shape, q, now=now if injected else None)
                flushed += 1
            if q and now - q[0].t_submit >= wait_s:
                self.stats.deadline_flushes += 1
                self._flush(shape, q, now=now if injected else None)
                flushed += 1
        return flushed

    def drain(self, now: Optional[float] = None) -> int:
        """Flush every non-empty bucket regardless of size or deadline
        (shutdown / end-of-stream).  Returns the number of flushes.
        ``now`` threads a virtual completion stamp exactly as in
        :meth:`poll`."""
        flushed = 0
        for shape, q in list(self._queues.items()):
            while q:
                self.stats.drain_flushes += 1
                self._flush(shape, q, now=now)
                flushed += 1
        return flushed

    def _flush(self, shape: tuple, q: deque,
               now: Optional[float] = None) -> None:
        p = self.params
        reqs = [q.popleft() for _ in range(min(len(q), p.serve_lanes))]
        if shape[0] == "update":
            results = self._solve_updates(reqs)
        else:
            results = self._solve_graphs(shape, reqs)
        # Completion stamp: the injected virtual time when the dispatcher
        # was driven with one (poll(now=...) — a single timebase for the
        # whole ledger), else the real clock AFTER the solve.
        done = self._clock() if now is None else now
        # Requests left the FIFO in arrival order; their futures complete
        # in that same order (ghost lanes have no future to complete).
        for r, res in zip(reqs, results):
            self.stats.completed += 1
            self.stats.record_latency((done - r.t_submit) * 1e3)
            r.future.set_result(res)

    def _dispatch_params(self, n_pad: int) -> GHSParams:
        """Solving params for one flush: a run-to-completion interval
        (``batch_check_frequency >= n_pad + 2``, the round bound) so the
        bucket converges in ONE dispatch — one readback per flush, and the
        mid-solve compaction ladder never runs, which keeps the warmed
        lattice at one executable per (shape, width).  (The default
        short-interval policy exists for throughput-scale batched solves,
        where per-interval contraction amortizes; at serving shapes it
        would instead demand O(shapes · ladder²) warmed executables —
        enough JIT code mappings to exhaust ``vm.max_map_count``.)  A
        user-set longer interval is kept."""
        p = self.params
        return dataclasses.replace(
            p, batch_check_frequency=max(p.batch_check_frequency,
                                         n_pad + 2))

    def _solve_graphs(self, shape: tuple, reqs: list) -> list:
        """One packed bucket dispatch at the pow2-rounded occupied width."""
        p = self.params
        lanes = min(pow2ceil(len(reqs)), p.serve_lanes)
        ghosts = lanes - len(reqs)
        graphs = [r.graph for r in reqs] + \
            [_ghost_graph() for _ in range(ghosts)]
        n_pad, cap = shape
        batch = pipeline.pack_bucket(graphs, n_pad, cap)
        results, _ = mst_api.solve_packed(
            batch, params=self._dispatch_params(n_pad),
            max_rounds=self._max_rounds)
        self.stats.ghost_lanes += ghosts
        return results[:len(reqs)]

    def _solve_updates(self, reqs: list) -> list:
        """Plan every update's cycle/cut probe, then solve ALL candidate
        subgraphs through one batched dispatch (DESIGN.md §13) — each lane
        bit-identical to a standalone ``mst_api.apply_updates``."""
        p = self.params
        plans = [incremental.plan_updates(r.forest, r.edge_batch,
                                          params=p, updated=r.graph)
                 for r in reqs]
        forests, _ = mst_api.minimum_spanning_forests(
            [pl.sub for pl in plans], params=p,
            max_rounds=self._max_rounds)
        out = []
        for pl, f in zip(plans, forests):
            self.stats.updates_applied += pl.stats.updates_applied
            self.stats.replacement_probes += pl.stats.replacement_probes
            out.append(incremental.finalize_plan(pl, f))
        return out

    # -- warmup ------------------------------------------------------------

    def flush_widths(self) -> list:
        """The lane widths an adaptive flush can dispatch at: every power
        of two below ``serve_lanes``, plus ``serve_lanes`` itself (a full
        or over-rounded flush caps there — ``min(pow2ceil(occupied),
        serve_lanes)`` can produce no other value)."""
        widths, w = [], 1
        while w < self.params.serve_lanes:
            widths.append(w)
            w *= 2
        widths.append(self.params.serve_lanes)
        return widths

    def warmup(self) -> int:
        """Precompile the pow2 bucket lattice: every ``(n_pad, cap)`` shape
        up to ``batch_max_vertices`` / ``batch_max_edges``, at every
        adaptive flush width (:meth:`flush_widths`) — after this, no
        runtime flush of an admissible solve request compiles anything,
        full-width or part-full.  Per (shape, width),
        :func:`repro.core.mst_api.warm_bucket` traces the vmapped interval
        fn at the load cap AND at every pow2 compaction cap below it, plus
        the shrink slices between them (the interval fn's cache key carries
        the bucket's contraction bits, so post-shrink retraces are NOT
        covered by smaller buckets' warmup; pipeline weights live in
        (0, 1), so the bit-gate resolves identically for empty warm lanes
        and real traffic).  Requires bounded capacities and the ``"pow2"``
        policy (``"exact"`` shapes are unbounded — they compile on first
        flush); returns the number of (shape, width) executables warmed.
        Update-kind flushes are not warmed here: their candidate subgraph
        shapes depend on the traffic's graphs, so they compile on first
        use like ``"exact"`` buckets."""
        p = self.params
        if (p.batch_bucket != "pow2" or not p.batch_max_vertices
                or not p.batch_max_edges):
            return 0
        n_top = pow2ceil(p.batch_max_vertices)
        cap_top = pow2ceil(max(p.batch_max_edges, 8))
        widths = self.flush_widths()
        warmed = 0
        n_pad = 1
        while n_pad <= n_top:
            wp = self._dispatch_params(n_pad)
            cap = 8
            while cap <= cap_top:
                for lanes in widths:
                    mst_api.warm_bucket(lanes, n_pad, cap, params=wp)
                    warmed += 1
                cap *= 2
            n_pad *= 2
        self.stats.buckets_warmed = warmed
        return warmed


# ---------------------------------------------------------------------------
# Open-loop Poisson driver — the benchmark's offered-load generator
# ---------------------------------------------------------------------------

def run_poisson(
    service: MSTService,
    graphs,
    *,
    rate: float,
    seed: int = 0,
    sleep: Callable[[float], None] = time.sleep,
) -> list:
    """Offer ``graphs`` to ``service`` as a Poisson stream of ``rate``
    graphs/second; returns one future per request (``None`` where the
    service shed it).

    Open-loop semantics: arrival times are drawn up front
    (exponential inter-arrival gaps, ``numpy`` Generator seeded with
    ``seed``) and requests are backdated to their SCHEDULED arrival via
    ``submit(t_arrival=...)`` — when a long flush makes the driver late,
    the measured latency still starts at the arrival the load model
    demanded, and the queue bound sheds honestly instead of the driver
    quietly throttling the offered load.  Between arrivals the driver
    polls the dispatcher, so deadline flushes fire on schedule.  The
    stream is drained at the end and ``stats.graphs_per_s`` is filled
    from the wall-clock span."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(graphs))
    clock = service._clock
    t0 = clock()
    arrivals = t0 + np.cumsum(gaps)
    futures: list = []
    for g, t_arr in zip(graphs, arrivals):
        while True:
            now = clock()
            if now >= t_arr:
                break
            service.poll(now)
            sleep(min(t_arr - now, 1e-3))
        try:
            futures.append(service.submit(g, t_arrival=float(t_arr)))
        except ShedError:
            futures.append(None)
        service.poll()
    service.poll()
    service.drain()
    span = clock() - t0
    service.stats.graphs_per_s = (service.stats.completed / span
                                  if span > 0 else 0.0)
    return futures


# ---------------------------------------------------------------------------
# CLI demo
# ---------------------------------------------------------------------------

def main(argv=None):
    from repro.core import generators, kruskal_ref

    ap = argparse.ArgumentParser(
        description="Continuous-batching MST service demo")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run: fewer requests, smaller graphs")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="offered load, graphs/second")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--max-vertices", type=int, default=256)
    ap.add_argument("--max-edges", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true")
    ap.add_argument("--verify", action="store_true",
                    help="check every served forest against the Kruskal "
                         "oracle")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 64)
        args.max_vertices = min(args.max_vertices, 64)
        args.max_edges = min(args.max_edges, 256)

    params = dataclasses.replace(
        DEFAULT_PARAMS,
        serve_lanes=args.lanes,
        serve_max_wait_ms=args.max_wait_ms,
        serve_max_queue=args.max_queue,
        batch_max_vertices=args.max_vertices,
        batch_max_edges=args.max_edges)
    service = MSTService(params)

    if not args.no_warmup:
        t0 = time.monotonic()
        warmed = service.warmup()
        print(f"warmup: {warmed} bucket shapes in "
              f"{time.monotonic() - t0:.1f}s")

    rng = np.random.default_rng(args.seed)
    scale_top = max(args.max_vertices.bit_length() - 1, 2)
    # Degree 8 keeps every scale inside --max-edges; a handful of
    # full-degree graphs ride along to exercise the oversize shed path.
    graphs = [
        generators.generate(
            "rmat", int(rng.integers(2, scale_top + 1)),
            avg_degree=8 if i % 16 else 32,
            seed=int(rng.integers(0, 2**31)))
        for i in range(args.requests)
    ]

    futures = run_poisson(service, graphs, rate=args.rate, seed=args.seed)

    if args.verify:
        for g, f in zip(graphs, futures):
            if f is None:
                continue
            res = f.result()
            oracle = kruskal_ref.kruskal(g)
            assert np.array_equal(res.edge_mask, oracle.edge_mask), \
                "served forest diverged from the Kruskal oracle"
        print("verify: all served forests oracle-exact")

    for k, v in service.stats.summary().items():
        print(f"{k:>18}: {v}")
    return service.stats


if __name__ == "__main__":
    main()
