import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import: jax locks the device
# count on first initialization. Do not move them.

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, record memory/cost analysis + collective bytes for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out benchmarks/artifacts/dryrun

Proves (per the deliverable): the sharding config is coherent (no sharding
mismatches / unsupported collectives), per-device memory fits, and yields
the HLO-derived roofline terms of EXPERIMENTS.md §Roofline.
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES, applicable
from repro.launch.mesh import make_production_mesh, make_rules
from repro.models.api import get_model, train_input_specs
from repro.models.config import ModelConfig
from repro.sharding.specs import (ShardingRules, param_shardings, shard,
                                  tree_paths, use_sharding, _axis_size)
from repro.launch.flops import cost_of
from repro.train.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import TrainHParams, init_train_state, \
    make_train_step

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(\S+?)\[([\d,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in post-SPMD HLO (per device)."""
    per_op: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(dtype, dims)
        slot = per_op.setdefault(op, {"count": 0, "bytes": 0})
        slot["count"] += 1
        slot["bytes"] += b
    total = sum(v["bytes"] for v in per_op.values())
    return {"per_op": per_op, "total_bytes": total}


def batch_axes_or_none(mesh, rules, dim: int):
    ax = rules.batch
    return ax if dim % _axis_size(mesh, ax) == 0 else None


# ---------------------------------------------------------------------------
# Input shardings
# ---------------------------------------------------------------------------

def train_batch_shardings(specs, mesh, rules):
    out = {}
    for k, v in specs.items():
        ba = batch_axes_or_none(mesh, rules, v.shape[0])
        out[k] = NamedSharding(mesh, P(*([ba] + [None] * (len(v.shape) - 1))))
    return out


def decode_state_shardings(cfg: ModelConfig, state_shapes, mesh, rules,
                           batch: int, cache_len: int):
    """Explicit per-family decode-state shardings (KV seq over the TP axis)."""
    ba = batch_axes_or_none(mesh, rules, batch)
    model = rules.model

    def kv_spec(shape):
        # (L, B, Hkv, S, hd) — shard S over model (always divisible: 2^k)
        seq_ok = shape[3] % _axis_size(mesh, model) == 0 if model else False
        return P(None, ba, None, model if seq_ok else None, None)

    def ns(spec):
        return NamedSharding(mesh, spec)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        cache = state_shapes
        return type(cache)(k=ns(kv_spec(cache.k.shape)),
                           v=ns(kv_spec(cache.v.shape)),
                           index=ns(P()))
    if fam == "encdec":
        cache, cross = state_shapes
        c = type(cache)(k=ns(kv_spec(cache.k.shape)),
                        v=ns(kv_spec(cache.v.shape)), index=ns(P()))
        return (c, (ns(kv_spec(cross[0].shape)), ns(kv_spec(cross[1].shape))))
    if fam == "ssm":
        d_ok = cfg.d_model % _axis_size(mesh, model) == 0 if model else False
        dm = model if d_ok else None
        return dict(
            tm=ns(P(None, ba, dm)), cm=ns(P(None, ba, dm)),
            wkv=ns(P(None, ba, None, None, None)))
    if fam == "hybrid":
        di_ok = cfg.d_inner % _axis_size(mesh, model) == 0 if model else False
        dm = model if di_ok else None
        return dict(
            conv=ns(P(None, None, ba, None, dm)),
            ssm=ns(P(None, None, ba, dm, None)),
            k=ns(kv_spec(state_shapes["k"].shape)),
            v=ns(kv_spec(state_shapes["v"].shape)),
            index=ns(P()))
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Cell builders: return (lowered, meta)
# ---------------------------------------------------------------------------

def lower_train(cfg, shape, mesh, rules):
    # Microbatch accumulation: cap per-device tokens per microbatch at 16k
    # (65k tokens/device at full batch blows the activation budget of every
    # >10B arch; grads accumulate in the sharded fp32 buffer).
    n_dev_batch = _axis_size(mesh, rules.batch)
    tokens_per_dev = shape.global_batch * shape.seq_len // max(n_dev_batch, 1)
    accum = max(1, tokens_per_dev // 16384)
    if cfg.param_count() > 4e10:
        accum = max(accum, 8)
    if cfg.family == "ssm":       # recurrent scan residuals are f32-heavy
        accum = max(accum, 8)
    hp = TrainHParams(remat="full", grad_accum=accum)
    step = make_train_step(cfg, hp)
    rng = jax.random.PRNGKey(0)
    state_shapes = jax.eval_shape(lambda r: init_train_state(r, cfg), rng)
    psh = param_shardings(state_shapes["params"], mesh, rules)
    state_sh = dict(params=psh,
                    opt=dict(m=psh, v=psh,
                             step=NamedSharding(mesh, P())))
    specs = train_input_specs(cfg, shape.global_batch, shape.seq_len)
    bsh = train_batch_shardings(specs, mesh, rules)
    with use_sharding(mesh, rules):
        lowered = jax.jit(
            step, in_shardings=(state_sh, bsh), donate_argnums=(0,),
        ).lower(state_shapes, specs)
        jc = cost_of(step, state_shapes, specs)
    tokens = shape.global_batch * shape.seq_len
    # 6·N_active·D counts fwd+bwd (2N fwd + 4N bwd per token), per the spec.
    model_flops = 6 * cfg.active_param_count() * tokens
    return lowered, dict(model_flops=model_flops, tokens=tokens,
                         jaxpr_flops_global=jc["flops"],
                         jaxpr_bytes_global=jc["bytes"],
                         jaxpr_unbounded_whiles=jc["while_bodies"])


def lower_prefill(cfg, shape, mesh, rules):
    pf = make_prefill_step(cfg, max_len=shape.seq_len)
    rng = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(
        lambda r: get_model(cfg).init(r, cfg), rng)
    psh = param_shardings(params_shapes, mesh, rules)
    specs = train_input_specs(cfg, shape.global_batch, shape.seq_len)
    specs.pop("labels")
    bsh = train_batch_shardings(specs, mesh, rules)
    # Explicit out_shardings: the produced KV caches must come out sharded
    # (B over data, cache-seq over model) or they'd be materialized
    # replicated — the dominant buffer at 32k.
    ba = batch_axes_or_none(mesh, rules, shape.global_batch)
    vdiv = cfg.vocab % _axis_size(mesh, rules.model) == 0
    logits_sh = NamedSharding(mesh,
                              P(ba, None, rules.model if vdiv else None))
    # NOTE: eval_shape must run INSIDE the sharding ctx — jax's trace cache
    # is shared with jit, and an un-ctx'd trace would pin the non-EP MoE
    # path into the compiled artifact (measured: 112 GiB ragged buffers).
    with use_sharding(mesh, rules):
        out_shapes = jax.eval_shape(pf, params_shapes, specs)
    cache_len = shape.seq_len + (cfg.n_frontend_tokens
                                 if cfg.family == "vlm" else 0)
    if cfg.family == "encdec":
        state_sh = decode_state_shardings(
            cfg, (out_shapes[1], out_shapes[2]), mesh, rules,
            shape.global_batch, shape.seq_len)
        osh = (logits_sh, state_sh[0], state_sh[1])
    else:
        osh = (logits_sh, decode_state_shardings(
            cfg, out_shapes[1], mesh, rules, shape.global_batch,
            cache_len))
    with use_sharding(mesh, rules):
        lowered = jax.jit(pf, in_shardings=(psh, bsh),
                          out_shardings=osh).lower(params_shapes, specs)
        jc = cost_of(pf, params_shapes, specs)
    tokens = shape.global_batch * shape.seq_len
    return lowered, dict(model_flops=2 * cfg.active_param_count() * tokens,
                         tokens=tokens,
                         jaxpr_flops_global=jc["flops"],
                         jaxpr_bytes_global=jc["bytes"],
                         jaxpr_unbounded_whiles=jc["while_bodies"])


def lower_decode(cfg, shape, mesh, rules):
    from repro.train.serve_step import decode_input_specs
    dstep = make_decode_step(cfg)
    rng = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(
        lambda r: get_model(cfg).init(r, cfg), rng)
    psh = param_shardings(params_shapes, mesh, rules)
    state_shapes, tok_spec = decode_input_specs(
        cfg, shape.global_batch, shape.seq_len)
    ssh = decode_state_shardings(cfg, state_shapes, mesh, rules,
                                 shape.global_batch, shape.seq_len)
    ba = batch_axes_or_none(mesh, rules, shape.global_batch)
    tsh = NamedSharding(mesh, P(ba, None))

    def fn(params, state, tokens):
        nxt, new_state, _ = dstep(params, state, tokens)
        return nxt, new_state

    with use_sharding(mesh, rules):
        lowered = jax.jit(fn, in_shardings=(psh, ssh, tsh),
                          donate_argnums=(1,)).lower(
            params_shapes, state_shapes, tok_spec)
        jc = cost_of(fn, params_shapes, state_shapes, tok_spec)
    tokens = shape.global_batch  # one token per sequence per step
    return lowered, dict(model_flops=2 * cfg.active_param_count() * tokens,
                         tokens=tokens,
                         jaxpr_flops_global=jc["flops"],
                         jaxpr_bytes_global=jc["bytes"],
                         jaxpr_unbounded_whiles=jc["while_bodies"])


BUILDERS = {"train": lower_train, "prefill": lower_prefill,
            "decode": lower_decode}


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh, rules, mesh_tag: str,
             out_dir: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_tag,
               status="skip", reason=why)
    if not ok:
        return rec
    t0 = time.time()
    try:
        lowered, meta = BUILDERS[shape.kind](cfg, shape, mesh, rules)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        mem = compiled.memory_analysis()
        coll = collective_bytes(compiled.as_text())
        n_dev = int(np.prod(mesh.devices.shape))
        rec.update(
            status="ok", reason="",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            n_devices=n_dev,
            flops_per_device=float(cost.get("flops", -1.0)),
            bytes_per_device=float(cost.get("bytes accessed", -1.0)),
            collectives=coll,
            memory=dict(
                argument_bytes=int(mem.argument_size_in_bytes),
                output_bytes=int(mem.output_size_in_bytes),
                temp_bytes=int(mem.temp_size_in_bytes),
                peak_bytes=int(mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes),
            ),
            **meta,
        )
    except Exception as e:  # noqa: BLE001 — record and continue
        rec.update(status="fail", reason=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multipod2x16x16",
                       make_production_mesh(multi_pod=True)))

    failures = 0
    for mesh_tag, mesh in meshes:
        rules = make_rules(mesh)
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh, rules, mesh_tag,
                               args.out)
                line = (f"[{mesh_tag}] {arch:24s} {shape_name:12s} "
                        f"{rec['status']:5s}")
                if rec["status"] == "ok":
                    nd = rec["n_devices"]
                    line += (f" compile={rec['compile_s']:6.1f}s "
                             f"jflops/dev={rec['jaxpr_flops_global']/nd:.3e} "
                             f"coll={rec['collectives']['total_bytes']/2**20:8.1f}MiB "
                             f"peak={rec['memory']['peak_bytes']/2**30:6.2f}GiB")
                elif rec["status"] == "fail":
                    failures += 1
                    line += f"  {rec['reason'][:120]}"
                else:
                    line += f"  ({rec['reason'][:60]})"
                print(line, flush=True)
    print(f"dryrun complete; failures={failures}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
