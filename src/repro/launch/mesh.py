"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: 16×16 = 256 chips (data × model).
Multi-pod: 2×16×16 = 512 chips with a leading "pod" axis (DP/FSDP across
pods — pod-crossing traffic is gradient reduction only, matching DCN-class
links between pods).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro import compat
from repro.sharding.specs import ShardingRules


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_rules(mesh: Mesh) -> ShardingRules:
    names = mesh.axis_names
    if "pod" in names:
        return ShardingRules(batch=("pod", "data"), model="model",
                             fsdp=("pod", "data"))
    if "data" in names:
        return ShardingRules(batch=("data",), model="model", fsdp=("data",))
    # single-axis CPU/test meshes
    ax = names[0]
    return ShardingRules(batch=(ax,), model=None, fsdp=(ax,))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over locally available (possibly forced-host) devices."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return compat.make_mesh((data, model), ("data", "model"))
