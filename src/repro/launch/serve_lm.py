"""Batched LM serving driver: prefill a prompt batch, decode N tokens/request.

    PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen1.5-0.5b \
        --smoke --batch 4 --prompt-len 64 --gen 32

(The MST serving loop lives in :mod:`repro.launch.serve`; this module keeps
the language-model demo path.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models.api import get_model, synth_batch
from repro.train.serve_step import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sample", default="greedy")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng, cfg)
    max_len = args.prompt_len + args.gen

    batch = synth_batch(args.seed, cfg, args.batch, args.prompt_len)
    batch.pop("labels")

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg, sample=args.sample))

    t0 = time.time()
    # make_prefill_step normalises every family to exactly (logits, state);
    # do NOT probe tuple arity here (encdec's native 3-tuple is wrapped).
    logits, state = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None]
    toks = [nxt]
    t0 = time.time()
    for i in range(args.gen - 1):
        nxt, state, _ = decode(params, state, nxt,
                               jax.random.fold_in(rng, i))
        toks.append(nxt)
    jax.block_until_ready(nxt)
    t_dec = time.time() - t0
    seqs = jnp.concatenate(toks, axis=1)
    tok_s = args.batch * (args.gen - 1) / max(t_dec, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s "
          f"({args.batch * args.prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode:  {args.gen - 1} steps in {t_dec:.2f}s ({tok_s:.1f} tok/s)")
    print("sample tokens:", np.asarray(seqs[0, :16]))
    return seqs


if __name__ == "__main__":
    main()
