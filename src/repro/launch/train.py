"""End-to-end training driver (runnable on CPU, scales to the pod mesh).

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Fault tolerance: periodic atomic checkpoints (async), SIGTERM triggers a
final save (preemption), --resume restores params/optimizer/data cursor and
reshards onto the *current* mesh (elastic restart).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import get_config, list_archs
from repro.data.tokens import DataConfig, make_dataset
from repro.launch.mesh import make_host_mesh, make_rules
from repro.models.api import synth_batch
from repro.sharding.specs import param_shardings, use_sharding
from repro.train import optimizer as opt_lib
from repro.train.train_step import TrainHParams, init_train_state, \
    make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM over local devices, e.g. 4x2")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh(d, m) if d * m > 1 else None
    rules = make_rules(mesh) if mesh else None

    hp = TrainHParams(
        remat=args.remat, grad_accum=args.grad_accum,
        adamw=opt_lib.AdamWConfig(lr=args.lr,
                                  compress_grads=args.compress_grads))
    step_fn = make_train_step(cfg, hp)

    rng = jax.random.PRNGKey(args.seed)
    state = init_train_state(rng, cfg)
    start_step = 0
    shardings = None
    if mesh is not None:
        psh = param_shardings(state["params"], mesh, rules)
        shardings = dict(params=psh, opt=dict(
            m=psh, v=psh, step=None))
        state = dict(
            params=jax.device_put(state["params"], psh),
            opt=dict(m=jax.device_put(state["opt"]["m"], psh),
                     v=jax.device_put(state["opt"]["v"], psh),
                     step=state["opt"]["step"]))

    if args.resume and args.ckpt_dir:
        last = ckpt_lib.latest_step(args.ckpt_dir)
        if last is not None:
            state, meta = ckpt_lib.restore(
                args.ckpt_dir, state,
                shardings=shardings if mesh is not None else None)
            start_step = meta["step"]
            print(f"resumed from step {start_step}", flush=True)

    data = make_dataset(
        DataConfig(kind=args.data, path=args.data_path, vocab=cfg.vocab,
                   seed=args.seed), args.batch, args.seq)

    stop = {"flag": False}

    def on_term(signum, frame):
        print("SIGTERM: saving and exiting", flush=True)
        stop["flag"] = True

    signal.signal(signal.SIGTERM, on_term)

    jitted = jax.jit(step_fn, donate_argnums=(0,))
    ctx = use_sharding(mesh, rules) if mesh is not None else _nullctx()
    t0 = time.time()
    with ctx:
        for step in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, data.batch_at(step))
            state, metrics = jitted(state, batch)
            if (step + 1) % args.log_every == 0 or step == start_step:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                dt = time.time() - t0
                tok_s = args.batch * args.seq * (step + 1 - start_step) / dt
                print(f"step {step + 1:5d} loss {loss:7.4f} "
                      f"gnorm {gn:8.3f} tok/s {tok_s:9.0f}", flush=True)
            if args.ckpt_dir and ((step + 1) % args.ckpt_every == 0
                                  or stop["flag"]
                                  or step + 1 == args.steps):
                ckpt_lib.save(args.ckpt_dir, step + 1, state)
            if stop["flag"]:
                break
    print("training done", flush=True)
    return state


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
