"""Scan-aware jaxpr cost counter for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scan-over-layers module is undercounted ~L×.  This counter walks the jaxpr
(post-AD, post-remat), multiplying scan bodies by their trip count and
recursing into pjit/checkpoint/custom-vjp sub-jaxprs — the result is the
number of FLOPs actually executed, *including* remat recompute (which is
exactly what the MODEL_FLOPS / HLO_FLOPs ratio in §Roofline must expose).

Bytes are fusion-naive (Σ operand+result sizes per equation): an upper
bound on HBM traffic, reported as such.  Both numbers are GLOBAL
(pre-partitioning); per-device = /n_devices under even sharding.
"""
from __future__ import annotations

import numpy as np

ELEMENTWISE_FREE = {
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "slice",
    "dynamic_slice", "dynamic_update_slice", "concatenate", "convert_element_type",
    "bitcast_convert_type", "gather", "scatter", "scatter-add", "rev", "pad",
    "iota", "copy", "stop_gradient",
}


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 — abstract tokens etc.
        return 0


def _dot_flops(eqn) -> int:
    dnums = eqn.params["dimension_numbers"]
    (lc, _rc), (lb, _rb) = dnums
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    return 2 * int(np.prod(out.shape)) * k


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # 2 * out_elems * (kernel spatial * in_features)
    return 2 * int(np.prod(out.shape)) * int(np.prod(rhs.shape[:-1]))


def jaxpr_cost(jaxpr, mult: int = 1) -> dict:
    """Returns dict(flops=..., bytes=..., while_bodies=N)."""
    flops = 0
    bites = 0
    whiles = 0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            sub = jaxpr_cost(eqn.params["jaxpr"].jaxpr, mult=1)
            length = eqn.params["length"]
            flops += mult * length * sub["flops"]
            bites += mult * length * sub["bytes"]
            whiles += sub["while_bodies"]
            continue
        if prim == "while":
            sub = jaxpr_cost(eqn.params["body_jaxpr"].jaxpr, mult=1)
            flops += mult * sub["flops"]       # trip count unknown: ×1 + flag
            bites += mult * sub["bytes"]
            whiles += 1 + sub["while_bodies"]
            continue
        if prim == "shard_map":
            sub = jaxpr_cost(eqn.params["jaxpr"], mult=1)
            n_dev = 1
            try:
                import math
                n_dev = math.prod(eqn.params["mesh"].shape.values())
            except Exception:  # noqa: BLE001
                pass
            flops += mult * n_dev * sub["flops"]
            bites += mult * n_dev * sub["bytes"]
            whiles += sub["while_bodies"]
            continue
        if prim == "cond":
            subs = [jaxpr_cost(b.jaxpr, mult=1)
                    for b in eqn.params["branches"]]
            flops += mult * max(s["flops"] for s in subs)
            bites += mult * max(s["bytes"] for s in subs)
            whiles += sum(s["while_bodies"] for s in subs)
            continue
        sub_key = None
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in eqn.params:
                sub_key = key
                break
        if sub_key is not None:
            subj = eqn.params[sub_key]
            subj = subj.jaxpr if hasattr(subj, "jaxpr") else subj
            sub = jaxpr_cost(subj, mult=1)
            flops += mult * sub["flops"]
            bites += mult * sub["bytes"]
            whiles += sub["while_bodies"]
            continue
        io_bytes = (sum(_aval_bytes(v.aval) for v in eqn.invars
                        if hasattr(v, "aval"))
                    + sum(_aval_bytes(v.aval) for v in eqn.outvars))
        if prim == "dot_general":
            flops += mult * _dot_flops(eqn)
            bites += mult * io_bytes
        elif prim == "conv_general_dilated":
            flops += mult * _conv_flops(eqn)
            bites += mult * io_bytes
        elif prim == "ragged_dot":
            # (T, D) x (E, D, F): 2*T*D*F effective (each row hits 1 expert)
            lhs = eqn.invars[0].aval
            rhs = eqn.invars[1].aval
            flops += mult * 2 * lhs.shape[0] * lhs.shape[1] * rhs.shape[2]
            bites += mult * io_bytes
        elif prim in ELEMENTWISE_FREE:
            bites += mult * io_bytes
        else:
            out_elems = sum(int(np.prod(v.aval.shape))
                            for v in eqn.outvars)
            flops += mult * out_elems            # 1 flop/element estimate
            bites += mult * io_bytes
    return dict(flops=int(flops), bytes=int(bites), while_bodies=whiles)


def cost_of(fn, *args) -> dict:
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args)
    return jaxpr_cost(jaxpr.jaxpr)
