"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128 experts top-8, qk-norm, hd=128."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=6144, vocab=151936, head_dim=128,
        qk_norm=True, rope_theta=1e6, n_experts=128, top_k=8, d_expert=768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=8, n_kv_heads=2, d_ff=192, vocab=512, head_dim=16,
        qk_norm=True, n_experts=16, top_k=8, d_expert=24,
        compute_dtype="float32",
    )
