"""Jamba v0.1 52B [arXiv:2403.19887] — Mamba+attn 1:7, MoE 16e top-2."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536,
        n_experts=16, top_k=2, d_expert=14336, moe_every=2, attn_every=8,
        d_state=16, d_conv=4, expand=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke", family="hybrid", n_layers=8, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=224, vocab=512, n_experts=4, top_k=2,
        d_expert=224, moe_every=2, attn_every=8, d_state=8, d_conv=4,
        expand=2, compute_dtype="float32",
    )
