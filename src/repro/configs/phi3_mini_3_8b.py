"""Phi-3-mini 3.8B [arXiv:2404.14219] — RoPE + SwiGLU, 32 KV heads (MHA)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense", n_layers=32, d_model=3072,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064, rope_theta=1e4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b-smoke", family="dense", n_layers=2, d_model=96,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
        compute_dtype="float32",
    )
