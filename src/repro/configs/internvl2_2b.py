"""InternVL2-2B [arXiv:2404.16821] — InternLM2-1.8B backbone; InternViT
frontend is a STUB (precomputed patch embeddings via input_specs)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
        n_heads=16, n_kv_heads=8, d_ff=8192, vocab=92553, rope_theta=1e6,
        d_frontend=1024, n_frontend_tokens=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, d_frontend=32,
        n_frontend_tokens=8, compute_dtype="float32",
    )
