"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 4 shared + 60 routed top-4."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=5632, vocab=151936, qkv_bias=True,
        rope_theta=1e6, n_experts=60, top_k=4, d_expert=1408,
        n_shared=4, d_shared=5632,   # 4 shared experts = one 4x1408 SwiGLU
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=176, vocab=512, qkv_bias=True,
        n_experts=8, top_k=4, d_expert=44, n_shared=4, d_shared=176,
        compute_dtype="float32",
    )
