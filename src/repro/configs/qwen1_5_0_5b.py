"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — QKV bias, tied embeddings."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=2816, vocab=151936, qkv_bias=True,
        rope_theta=1e6, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=176, vocab=512, qkv_bias=True,
        tie_embeddings=True, compute_dtype="float32",
    )
