"""SeamlessM4T-large v2 backbone [arXiv:2308.11596] — enc-dec; audio
frontend is a STUB (precomputed frame embeddings via input_specs)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec", n_layers=24,
        n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206, d_frontend=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-smoke", family="encdec", n_layers=2,
        n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512, d_frontend=32, compute_dtype="float32",
    )
