"""The four assigned input-shape sets + applicability rules."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (SSM / hybrid); all archs here
    have decoders so decode shapes always apply (DESIGN.md §5)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "pure full-attention arch: long_500k skipped per spec"
    return True, ""
