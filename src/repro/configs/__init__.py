"""Architecture registry: --arch <id> -> ModelConfig (+ reduced smoke)."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = {
    "qwen2-moe-a2.7b":       "repro.configs.qwen2_moe_a2_7b",
    "qwen3-moe-30b-a3b":     "repro.configs.qwen3_moe_30b_a3b",
    "qwen2.5-32b":           "repro.configs.qwen2_5_32b",
    "phi3-mini-3.8b":        "repro.configs.phi3_mini_3_8b",
    "qwen1.5-0.5b":          "repro.configs.qwen1_5_0_5b",
    "qwen2.5-14b":           "repro.configs.qwen2_5_14b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "internvl2-2b":          "repro.configs.internvl2_2b",
    "rwkv6-3b":              "repro.configs.rwkv6_3b",
    "jamba-v0.1-52b":        "repro.configs.jamba_v0_1_52b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    return mod.smoke_config() if smoke else mod.config()


def list_archs():
    return sorted(ARCHS)
