"""Qwen2.5-14B — dense GQA, QKV bias [family source hf:Qwen/Qwen2.5-0.5B]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=13824, vocab=152064, qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke", family="dense", n_layers=2, d_model=80,
        n_heads=5, n_kv_heads=1, d_ff=108, vocab=512, qkv_bias=True,
        compute_dtype="float32",
    )
