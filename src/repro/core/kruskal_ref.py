"""Sequential Kruskal oracle (numpy) for validating the distributed engines.

Edges are scanned in packed-key order (weight, then unique edge id), the SAME
total order every engine uses, so the minimum spanning forest is unique and
engines can be compared edge-set-exactly, not just by total weight.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass(frozen=True)
class ForestResult:
    """Minimum spanning forest summary."""

    total_weight: float
    edge_mask: np.ndarray      # (M,) bool — canonical edges in the forest
    num_components: int        # connected components of the input graph
    num_tree_edges: int

    def check_consistent(self, num_vertices: int) -> None:
        assert self.num_tree_edges == int(self.edge_mask.sum())
        assert self.num_tree_edges == num_vertices - self.num_components


class _DSU:
    __slots__ = ("parent",)

    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)

    def find(self, x: int) -> int:
        p = self.parent
        root = x
        while p[root] != root:
            root = p[root]
        while p[x] != root:   # path compression
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        self.parent[rb] = ra
        return True


def kruskal(graph: Graph) -> ForestResult:
    order = np.argsort(graph.packed_keys, kind="stable")
    dsu = _DSU(graph.num_vertices)
    mask = np.zeros(graph.num_edges, dtype=bool)
    taken = 0
    src, dst = graph.src, graph.dst
    for e in order:
        if dsu.union(int(src[e]), int(dst[e])):
            mask[e] = True
            taken += 1
            if taken == graph.num_vertices - 1:
                break
    total = float(graph.weight[mask].sum(dtype=np.float64))
    # count components
    roots = {dsu.find(v) for v in range(graph.num_vertices)}
    res = ForestResult(
        total_weight=total,
        edge_mask=mask,
        num_components=len(roots),
        num_tree_edges=taken,
    )
    res.check_consistent(graph.num_vertices)
    return res


def boruvka_numpy(graph: Graph) -> ForestResult:
    """Vectorized numpy Borůvka — fast oracle for large graphs.

    Independent from the JAX engines (different control flow, same total
    order), so cross-checking the three implementations is meaningful.
    """
    n, m = graph.num_vertices, graph.num_edges
    key = graph.packed_keys
    src = graph.src.astype(np.int64)
    dst = graph.dst.astype(np.int64)
    comp = np.arange(n, dtype=np.int64)
    mask = np.zeros(m, dtype=bool)
    inf = np.uint64(0xFFFFFFFFFFFFFFFF)
    alive = np.ones(m, dtype=bool)
    while True:
        cs, cd = comp[src], comp[dst]
        alive &= cs != cd
        if not alive.any():
            break
        best = np.full(n, inf, dtype=np.uint64)
        a = np.flatnonzero(alive)
        np.minimum.at(best, cs[a], key[a])
        np.minimum.at(best, cd[a], key[a])
        moe = best != inf
        eids = (best[moe] & np.uint64(0xFFFFFFFF)).astype(np.int64)
        eids = np.unique(eids)
        mask[eids] = True
        # hook: union via pointer-jumping on a parent array
        parent = np.arange(n, dtype=np.int64)
        u, v = comp[src[eids]], comp[dst[eids]]
        lo, hi = np.minimum(u, v), np.maximum(u, v)
        parent[hi] = lo          # deterministic hooking (min root wins)
        # resolve chains: repeat until fixpoint
        while True:
            nxt = parent[parent]
            if np.array_equal(nxt, parent):
                break
            parent = nxt
        comp = parent[comp]
    total = float(graph.weight[mask].sum(dtype=np.float64))
    ncomp = np.unique(comp).size
    res = ForestResult(
        total_weight=total,
        edge_mask=mask,
        num_components=int(ncomp),
        num_tree_edges=int(mask.sum()),
    )
    res.check_consistent(n)
    return res
