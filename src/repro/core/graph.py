"""Graph containers + paper §3.1 preprocessing (self-loop / multi-edge removal).

Canonical storage is an undirected edge list ``(src < dst, weight)`` in numpy
(host memory — graphs can exceed device memory; shards are materialized on
demand).  The vertex-centric faithful engine additionally uses a CSR adjacency
over BOTH directions, matching the paper's per-process CRS layout (§3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np

from repro.core import keys as keys_lib


@dataclasses.dataclass(frozen=True)
class Graph:
    """Preprocessed undirected weighted graph (no loops, no multi-edges)."""

    num_vertices: int
    src: np.ndarray      # (M,) int32, src < dst
    dst: np.ndarray      # (M,) int32
    weight: np.ndarray   # (M,) float32, in (0, 1)

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @functools.cached_property
    def packed_keys(self) -> np.ndarray:
        """uint64 sortable (weight ‖ edge_id) keys — see keys.py (C3/C6).

        Cached: every ``pad_edges`` / repartition / oracle call reuses one
        array (the graph is frozen, so the keys can never go stale)."""
        eid = np.arange(self.num_edges, dtype=np.uint32)
        return keys_lib.pack_keys_np(self.weight, eid)

    def validate(self) -> None:
        assert self.src.dtype == np.int32 and self.dst.dtype == np.int32
        assert self.weight.dtype == np.float32
        if self.num_edges:
            assert int(self.src.min()) >= 0
            assert int(self.dst.max()) < self.num_vertices
            assert np.all(self.src < self.dst), "edges must be canonical (u < v)"
            pair = pair_ids(self.src, self.dst, self.num_vertices)
            assert np.unique(pair).size == pair.size, "multi-edges present"


@dataclasses.dataclass(frozen=True)
class CSRAdjacency:
    """Both-direction adjacency; ``edge_index`` maps back to canonical edges."""

    indptr: np.ndarray      # (N+1,) int64
    neighbor: np.ndarray    # (2M,) int32
    edge_index: np.ndarray  # (2M,) int32

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)


def pair_ids(u: np.ndarray, v: np.ndarray, num_vertices: int) -> np.ndarray:
    """Unique uint64 id per vertex pair — requires vertex ids to fit the
    32-bit lanes of the packing, checked here (the one place the whole
    codebase assumes it)."""
    assert num_vertices < 2 ** 32, (
        f"pair_ids packs vertex ids into 32-bit lanes; num_vertices="
        f"{num_vertices} overflows them")
    return (u.astype(np.uint64) << np.uint64(32)) | v.astype(np.uint64)


def preprocess(
    src: np.ndarray, dst: np.ndarray, weight: np.ndarray, num_vertices: int
) -> Graph:
    """Paper §3.1: drop self-loops, canonicalize u<v, dedup multi-edges.

    Among duplicates we keep the minimum-weight copy (the only
    correctness-preserving choice for MST on the underlying multigraph).
    """
    src = np.asarray(src).astype(np.int64)
    dst = np.asarray(dst).astype(np.int64)
    weight = np.asarray(weight, dtype=np.float32)
    keep = src != dst
    src, dst, weight = src[keep], dst[keep], weight[keep]
    u = np.minimum(src, dst)
    v = np.maximum(src, dst)
    pid = pair_ids(u, v, num_vertices)
    # Sort by (pair, weight) then keep the first occurrence of each pair.
    order = np.lexsort((weight, pid))
    pid, u, v, weight = pid[order], u[order], v[order], weight[order]
    first = np.ones(pid.shape[0], dtype=bool)
    first[1:] = pid[1:] != pid[:-1]
    g = Graph(
        num_vertices=int(num_vertices),
        src=u[first].astype(np.int32),
        dst=v[first].astype(np.int32),
        weight=weight[first],
    )
    return g


def both_direction_arrays(
    graph: Graph,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Unsorted both-direction incidence: (ends, neighbors, edge ids).

    The ONE home for the mirroring convention (canonical edge i appears as
    entries i and i+M); every adjacency builder sorts these by its own key
    (neighbor id for :func:`build_csr`, packed weight key for the GHS
    shards) so the structures can never drift apart.
    """
    m = graph.num_edges
    ends = np.concatenate([graph.src, graph.dst]).astype(np.int64)
    nbrs = np.concatenate([graph.dst, graph.src]).astype(np.int64)
    eidx = np.concatenate([np.arange(m, dtype=np.int64)] * 2)
    return ends, nbrs, eidx


def vertex_indptr(ends: np.ndarray, num_vertices: int) -> np.ndarray:
    """CSR window offsets from (sorted-by-vertex) incidence endpoints."""
    counts = np.bincount(ends, minlength=num_vertices).astype(np.int64)
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr


def build_csr(graph: Graph) -> CSRAdjacency:
    """Both-direction CSR; neighbor lists sorted by neighbor id (paper §3.3's
    "sorted incident edges" variant, which we get for free by construction)."""
    ends, nbrs, eidx = both_direction_arrays(graph)
    order = np.lexsort((nbrs, ends))
    ends, nbrs, eidx = ends[order], nbrs[order], eidx[order]
    return CSRAdjacency(
        indptr=vertex_indptr(ends, graph.num_vertices),
        neighbor=nbrs.astype(np.int32),
        edge_index=eidx.astype(np.int32),
    )


# Fill value for padded src/dst slots.  Deliberately FAR out of any vertex
# range: device gathers clamp it to the last vertex on both endpoints, so a
# padding edge is a self-loop by construction — inert in every engine even if
# its weight lane were ever misinitialized.  (Filling with vertex 0, as early
# versions did, made padding edges self-loops only by luck of the INF weight;
# a graph whose vertex 0 is isolated exposes the hazard.)
PAD_VERTEX = np.int32(0x7FFF0000)


def pad_edges(
    graph: Graph, multiple: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pad (src, dst, key, valid) so the edge count divides ``multiple``.

    Padding edges are (PAD_VERTEX, PAD_VERTEX) with INF_KEY and valid=False —
    inert under min-reductions, so shards stay rectangular (SPMD requirement).
    """
    m = graph.num_edges
    pad = (-m) % multiple
    src = np.concatenate([graph.src, np.full(pad, PAD_VERTEX, np.int32)])
    dst = np.concatenate([graph.dst, np.full(pad, PAD_VERTEX, np.int32)])
    key = np.concatenate(
        [graph.packed_keys, np.full(pad, keys_lib.INF_KEY, np.uint64)]
    )
    valid = np.concatenate([np.ones(m, bool), np.zeros(pad, bool)])
    return src, dst, key, valid
