"""Public MST API — unified front-end over the two engines."""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

from repro.core import boruvka_dist, ghs_message
from repro.core.graph import Graph
from repro.core.kruskal_ref import ForestResult
from repro.core.params import DEFAULT_PARAMS, GHSParams

METHODS = ("ghs", "boruvka")


def minimum_spanning_forest(
    graph: Graph,
    method: str = "boruvka",
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    **kw,
) -> tuple[ForestResult, object]:
    """Compute the minimum spanning forest of ``graph``.

    method='ghs'     — paper-faithful message-driven GHS (the reproduction).
    method='boruvka' — TPU-native synchronous engine (beyond-paper optimized);
                       ``params.round_loop`` picks the device-resident fused
                       loop (default) or the legacy host-driven loop.

    Both return (ForestResult, stats); the forest is bit-identical between
    engines and loop drivers (and to the Kruskal oracle) because all of them
    elect edges under the same packed (weight, edge-id) total order of
    :mod:`repro.core.keys`.
    """
    if method == "ghs":
        return ghs_message.minimum_spanning_forest(
            graph, params=params, mesh=mesh, **kw)
    if method == "boruvka":
        return boruvka_dist.minimum_spanning_forest(
            graph, params=params, mesh=mesh, **kw)
    raise ValueError(f"unknown method {method!r}; options: {METHODS}")
