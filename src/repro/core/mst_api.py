"""Public MST API — thin façade over the two engines.

Engine drivers, stats protocol, and the ``round_loop`` knob live in
:mod:`repro.core.runtime` (DESIGN.md §6); this module only selects the
engine.
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

from repro.core import (boruvka_dist, filter_boruvka, ghs_message,
                        incremental, runtime)
from repro.core.kruskal_ref import ForestResult
from repro.core.params import DEFAULT_PARAMS, GHSParams

METHODS = ("ghs", "boruvka", "filter_boruvka")

_ENGINES = {
    "ghs": ghs_message.minimum_spanning_forest,
    "boruvka": boruvka_dist.minimum_spanning_forest,
    "filter_boruvka": filter_boruvka.minimum_spanning_forest,
}


def minimum_spanning_forest(
    graph,
    method: str = "boruvka",
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    **kw,
) -> tuple[ForestResult, runtime.EngineStats]:
    """Compute the minimum spanning forest of ``graph``.

    ``graph`` is a host :class:`Graph` or a device-resident
    :class:`repro.core.pipeline.DeviceEdges` from the sharded graph
    pipeline — the Borůvka engine consumes the latter without an edge
    round-trip through host memory (DESIGN.md §7).

    method='ghs'     — paper-faithful message-driven GHS (the reproduction).
    method='boruvka' — TPU-native synchronous engine (beyond-paper optimized).
    method='filter_boruvka' — sample→solve→filter→solve hybrid (DESIGN.md
        §10): a counter-based Bernoulli edge sample is solved with the
        Borůvka engine, the quantized cycle rule drops provably-non-MSF
        edges against the partial forest, and the final solve runs over
        the survivors — expected-linear work on dense graphs
        (``params.filter_sample_rate`` / ``filter_levels`` /
        ``filter_threshold``).

    For BOTH engines ``params.round_loop`` picks the device-resident fused
    loop (default — at most one host sync per ``check_frequency`` interval)
    or the legacy host-driven loop, and ``params.partitioner`` picks the
    graph distribution (block / hashed / balanced, applied to edges for
    Borůvka and to vertices for GHS — :mod:`repro.core.partition`).  For
    the Borůvka device loop ``params.round_kernel`` additionally picks the
    round body: ``"xla"`` (per-edge scatter/gather chain, the default) or
    ``"pallas"`` (fused masked min-plus election via
    :mod:`repro.kernels.spmv_minplus` — DESIGN.md §9).  All return
    ``(ForestResult, stats)`` with ``stats`` deriving from
    :class:`repro.core.runtime.EngineStats`; the forest is bit-identical
    between engines, loop drivers, round kernels, and partitioners (and to
    the Kruskal oracle) because all of them elect edges under the same
    packed (weight, edge-id) total order of :mod:`repro.core.keys`.
    """
    try:
        engine = _ENGINES[method]
    except KeyError:
        raise ValueError(
            f"unknown method {method!r}; options: {METHODS}") from None
    return engine(graph, params=params, mesh=mesh, **kw)


def minimum_spanning_forests(
    graphs,
    method: str = "boruvka",
    params: GHSParams = DEFAULT_PARAMS,
    max_rounds=None,
) -> tuple[list, runtime.EngineStats]:
    """Compute minimum spanning forests for MANY graphs at once.

    The batched serving path (DESIGN.md §8): graphs are bucketed by padded
    shape (``params.batch_bucket`` policy, capacity-guarded by
    ``params.batch_max_vertices`` / ``batch_max_edges``) and each bucket
    runs the Borůvka round loop under ``jax.vmap`` — one dispatch and one
    scalar readback per interval for the whole bucket, instead of one
    engine invocation per graph.  Returns ``(forests, stats)`` with
    ``forests`` in input order; each forest is bit-identical to the
    single-graph :func:`minimum_spanning_forest` solve of the same graph
    (and to the Kruskal oracle), and ``stats.rounds_per_graph`` carries the
    per-graph round counts.

    Only the Borůvka engine has a batched fast path; ``method="ghs"``
    raises (the message-driven engine is served one graph at a time).
    ``params.round_loop == "host"`` falls back to a loop of single solves
    — the measured baseline of ``benchmarks/bench_batched.py``.
    """
    if method != "boruvka":
        raise ValueError(
            f"batched solving supports method='boruvka' only, got "
            f"{method!r}; solve GHS queries one graph at a time via "
            f"minimum_spanning_forest")
    return boruvka_dist.minimum_spanning_forests(
        graphs, params=params, max_rounds=max_rounds)


def solve_packed(
    batch,
    params: GHSParams = DEFAULT_PARAMS,
    max_rounds=None,
) -> tuple[list, runtime.EngineStats]:
    """Solve one pre-packed :class:`repro.core.pipeline.GraphBatch`.

    The incremental serving entry (DESIGN.md §12): the continuous-batching
    loop in :mod:`repro.launch.serve` admits requests per-bucket via
    :func:`repro.core.pipeline.bucket_shape`, packs a flushed queue with
    :func:`repro.core.pipeline.pack_bucket`, and dispatches it here — one
    vmapped device solve per flush, results in lane order, each forest
    bit-identical to the single-graph solve.
    """
    return boruvka_dist.solve_packed(
        batch, params=params, max_rounds=max_rounds)


def incremental_forest(
    graph,
    method: str = "boruvka",
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    **kw,
) -> tuple[incremental.IncrementalForest, runtime.EngineStats]:
    """Solve ``graph`` and wrap it as the evolving-graph handle that
    :func:`apply_updates` consumes.  Any engine works — forests are
    bit-identical across methods, so the handle is too."""
    res, stats = minimum_spanning_forest(
        graph, method=method, params=params, mesh=mesh, **kw)
    return incremental.IncrementalForest(
        graph=runtime.as_graph(graph), forest=res), stats


def apply_updates(
    forest: incremental.IncrementalForest,
    edge_batch: incremental.EdgeBatch,
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    max_rounds=None,
) -> tuple[incremental.IncrementalForest, incremental.IncrementalStats]:
    """Apply one batched insert/delete update to a solved forest.

    The incremental pass (DESIGN.md §13): the updated graph is
    :func:`repro.core.incremental.apply_edge_batch` of the inputs, the
    surviving tree edges anchor a device-resident cycle/cut probe (one
    fused mask readback per batch), and the Borůvka engine re-solves only
    the un-certified candidates — the returned forest is bit-identical to
    a from-scratch :func:`minimum_spanning_forest` of the updated graph,
    at any shard count.  ``stats.updates_applied`` /
    ``stats.replacement_probes`` meter the pass (runtime stats protocol).
    """
    return incremental.apply_updates(
        forest, edge_batch, params=params, mesh=mesh, max_rounds=max_rounds)


def warm_bucket(
    batch_size: int,
    n_pad: int,
    cap: int,
    params: GHSParams = DEFAULT_PARAMS,
) -> int:
    """Precompile every executable a bucket shape can touch during a solve
    (serving warmup — see :func:`repro.core.boruvka_dist.warm_bucket`)."""
    return boruvka_dist.warm_bucket(batch_size, n_pad, cap, params=params)
