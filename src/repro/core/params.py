"""Algorithm parameters — names and defaults follow the paper §3.6."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GHSParams:
    """Tunables of the distributed MST engines.

    Paper §3.6 defaults, with TPU-adaptation notes:
      * ``max_msg_size``       — capacity (in messages) of each per-destination
        aggregation bucket per superstep (paper: 10000 bytes).
      * ``sending_frequency``  — supersteps between bucket flushes.  In the BSP
        engine every superstep ends with one fused exchange, so the knob
        becomes how many local process passes run between exchanges.
      * ``check_frequency``    — supersteps between drains of the deferred
        ``Test`` queue (faithful engine) / rounds between edge compactions
        (optimized engine).  This is the paper's key contribution (C1).
      * ``empty_iter_cnt_to_break`` — how many CONSECUTIVE silent activity
        checks (global queue+in-flight census == 0) must be observed before
        the engine halts (paper §3.6).  Each superstep's psum silence check
        counts as one observation; any activity resets the streak.  Values
        > 1 add exactly ``empty_iter_cnt_to_break - 1`` confirmation
        supersteps after first silence and never change the forest (a
        silent engine has no in-flight messages left to revive it).
      * ``hash_table_factor``  — hash table slots per local edge (paper:
        5 * 11 / 13 ≈ 4.23).
      * ``queue_capacity``     — override for the faithful engine's message
        ring capacity (default: sized from the shard's adjacency so
        overflow is impossible on well-formed runs).  Small values exercise
        the ``ERR_QUEUE_OVERFLOW`` error path deterministically.
    """

    max_msg_size: int = 4096
    sending_frequency: int = 1
    check_frequency: int = 5
    empty_iter_cnt_to_break: int = 1
    hash_table_factor: float = 5 * 11 / 13
    queue_capacity: int = 0           # 0 = auto-size from the shard adjacency
    # Optimization toggles (Fig 2 ablation ladder).
    use_hashing: bool = True          # C2: hash edge lookup vs linear search
    relaxed_test_queue: bool = True   # C1: separate Test queue
    compress_messages: bool = True    # C3: bit-packed message words
    # Engine-runtime extras (beyond paper) — shared by BOTH engines.
    compaction: str = "pow2"          # 'none' | 'pow2' lazy edge compaction
    use_pallas: bool = False          # route segment-min through the Pallas kernel
    partitioner: str = "block"        # graph distribution (DESIGN.md §7):
                                      # 'block' — contiguous slots / vertex ids
                                      #   (today's layout)
                                      # 'hashed' — pseudo-random scatter
                                      # 'balanced' — degree/edge-balanced
                                      # Edges for the Borůvka engine, vertices
                                      # (via relabeling) for GHS; every choice
                                      # yields a bit-identical forest.
    round_loop: str = "device"        # 'device': fused lax.while_loop engine
                                      #   (≤ 1 host sync per check_frequency
                                      #   interval, both engines)
                                      # 'host': legacy per-round / per-superstep
                                      #   host loop
    collective: str = "pmin"          # cross-shard per-round reduction
                                      # (DESIGN.md §11):
                                      # 'pmin' — full-width lax.pmin over the
                                      #   replicated (n,) arrays (seed
                                      #   behavior)
                                      # 'compressed' — delta exchange: each
                                      #   shard ships only the entries it
                                      #   improved this round as packed
                                      #   (index, value) candidate lists on a
                                      #   ppermute ring, with a bit-identity
                                      #   lax.pmin fallback when a shard
                                      #   overflows the static cap.  Forests
                                      #   are bit-identical either way; bytes
                                      #   shrink with the active edge count.
    interval_pipeline: int = 1        # interval dispatch depth (DESIGN.md
                                      # §11): 1 double-buffers the device
                                      # round loops (interval k+1 is
                                      # dispatched before interval k's fused
                                      # scalar readback is consumed, hiding
                                      # host-sync latency); 0 is the
                                      # sequential dispatch→readback→decide
                                      # loop.  Forests are byte-identical
                                      # either way; legacy host loops are
                                      # always sequential.
    round_kernel: str = "xla"         # Borůvka round body (DESIGN.md §9):
                                      # 'xla' — per-edge scatter/gather chain
                                      #   (_one_round, the seed behavior)
                                      # 'pallas' — fused masked min-plus
                                      #   election (kernels/spmv_minplus) with
                                      #   n-scale recording/hooking and one
                                      #   collective per round; with
                                      #   use_pallas=True the election and
                                      #   shortcut run as Pallas kernels
                                      #   (interpret mode on CPU), otherwise
                                      #   the scatter-free sort lowering.
                                      #   Bit-identical forests either way.
    # Batched solving knobs (DESIGN.md §8) — minimum_spanning_forests only.
    batch_bucket: str = "pow2"        # pack_batch shape-bucketing policy:
                                      # 'pow2' rounds (n, m) up to powers of
                                      #   two so mixed sizes share executables
                                      # 'exact' buckets identical shapes only
    batch_max_vertices: int = 0       # per-graph capacity bounds for the
    batch_max_edges: int = 0          # batched path; 0 = unlimited, otherwise
                                      # pack_batch REJECTS oversized graphs
                                      # (ValueError), never truncates them
    batch_check_frequency: int = 1    # rounds per batched interval.  The
                                      # batched loop trades differently from
                                      # the single-graph one: its readback
                                      # amortizes over the whole bucket while
                                      # per-interval contraction shrinks every
                                      # subsequent round, so SHORT intervals
                                      # win (single-graph check_frequency is
                                      # untouched)
    # Filter-Borůvka sampling hybrid (DESIGN.md §10) — method="filter_boruvka".
    filter_sample_rate: float = 0.15  # Bernoulli keep probability of the
                                      # counter-based edge sample (splitmix64
                                      # over canonical edge ids — deterministic
                                      # at any shard count).  0 disables the
                                      # sample solve entirely (the final solve
                                      # then sees every edge — the empty-sample
                                      # guarantee); ≥ 1 samples everything.
    filter_levels: int = 16           # threshold levels of the connectivity
                                      # probe: the cycle rule is evaluated
                                      # against fragment labels of the sampled
                                      # forest restricted to tree edges below
                                      # per-level key quantiles.  More levels
                                      # → sharper path-max bound → fewer
                                      # survivors; never affects correctness.
    filter_threshold: int = 0         # survivor-count bound that triggers the
                                      # single recursion (a second
                                      # sample→solve→filter pass over the
                                      # survivors).  0 = auto: 4·num_vertices.
    # Incremental updates (DESIGN.md §13) — core/incremental.apply_updates.
    update_levels: int = 0            # threshold levels of the incremental
                                      # cycle probe (anchor-forest labels per
                                      # key quantile, plus the packed max-key
                                      # bound).  More levels → fewer
                                      # candidates reach the final solve;
                                      # never affects correctness.
                                      # 0 = follow filter_levels.
    # Serving knobs (DESIGN.md §12) — launch/serve.py continuous batching.
    serve_lanes: int = 8              # dispatch batch size: a bucket queue
                                      # flushes when it holds this many
                                      # graphs (or its deadline expires);
                                      # part-full flushes dispatch at the
                                      # pow2-rounded OCCUPIED lane count
                                      # (ghost-padded up to it, capped
                                      # here), so a solo deadline flush
                                      # pays a width-1 solve, not a
                                      # full-width one — warmup traces
                                      # every such width per bucket shape
    serve_max_wait_ms: float = 50.0   # deadline: the oldest queued request
                                      # waits at most this long before its
                                      # bucket is flushed part-full
    serve_max_queue: int = 64         # per-bucket admission bound; submits
                                      # beyond it are shed with
                                      # QueueFullError (backpressure, never
                                      # silent drops)


DEFAULT_PARAMS = GHSParams()
