"""Shared engine runtime — the interval-driven driver both MST engines use.

DESIGN.md §6.  Both engines (the paper-faithful message GHS and the
synchronous Borůvka reformulation) follow the same execution shape once
their inner loops are device-resident:

    compile a fused *interval* function   (lax.while_loop over N steps)
    loop:  dispatch one interval          (state stays on device)
           read back ONE fused scalar vector
           host decides: done? error? re-bucket/compact?

This module owns the pieces that are engine-independent:

* :func:`interval_loop` — the host driver harness.  Per interval it performs
  exactly one blocking device→host transfer (``jax.device_get`` on the
  dispatch's scalar outputs) and one ``host_syncs``/``intervals`` ledger
  update, then hands the scalars to an engine-specific ``finish`` hook that
  interprets them (raise on error flags, count rounds/supersteps, trigger
  compaction) and decides termination.  With ``overlap=True`` (DESIGN.md
  §11) the loop is double-buffered: interval k+1 is dispatched *before*
  interval k's scalar readback is consumed, so the blocking transfer hides
  behind in-flight device work — see the function docstring for the
  contract a pipelined engine must satisfy.
* :class:`EngineStats` — the unified stats protocol: every engine's stats
  object derives from it so benchmarks can meter host syncs and interval
  counts uniformly.
* :func:`donation` — ``donate_argnums`` selection: state buffers are donated
  for in-place reuse on backends that implement donation (CPU does not;
  donating there only emits warnings).
* :func:`forest_from_mask` — the shared forest-extraction path from a
  canonical edge bitmap to a :class:`ForestResult`.
* :func:`resolve_round_loop` — validation of the ``params.round_loop`` knob
  shared by both engines (``"device"`` fused loop / ``"host"`` legacy).
* :func:`prepare_edges` / :func:`vertex_partitioned` — the partition layer
  (DESIGN.md §7): both engines receive their input through these, so the
  ``params.partitioner`` knob and the device pipeline's no-host-round-trip
  hand-off live in ONE place.  ``prepare_edges`` accepts a host
  :class:`Graph` *or* a device-resident
  :class:`repro.core.pipeline.DeviceEdges` and returns an
  :class:`EdgeBundle` in engine layout; ``vertex_partitioned`` realizes a
  vertex partition for the block-routed GHS engine as a relabeling that
  preserves canonical edge ids (forests stay bit-identical).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

from repro.core import partition as partition_lib
from repro.core.graph import PAD_VERTEX, Graph
from repro.core.kruskal_ref import ForestResult

ROUND_LOOPS = ("device", "host")
ROUND_KERNELS = ("xla", "pallas")
INTERVAL_PIPELINES = (0, 1)


@dataclasses.dataclass
class EngineStats:
    """Host↔device traffic ledger common to every engine driver.

    ``host_syncs`` counts blocking transfer points: :func:`interval_loop`
    adds exactly one per consumed interval readback, and engine hooks add
    one for every blocking transfer they perform OUTSIDE the interval
    readback — mirrored into ``extra_syncs`` at the same site.  The
    pipeline-invariant contract, asserted by the cross-engine contract
    test, is therefore

        ``host_syncs == intervals + extra_syncs``

    with the engine-specific ``extra_syncs`` values:

    * single-graph device loops (Borůvka, GHS) — 1, the final state fetch,
      so ``host_syncs == intervals + 1`` for THOSE engines only;
    * the batched driver (DESIGN.md §8) — one final mask fetch per bucket,
      so ``extra_syncs == buckets``;
    * the filter hybrid (DESIGN.md §10) — the sub-solves' final fetches
      plus one keep-mask fetch per filter pass, summed through
      ``BatchStats.merge``;
    * legacy host loops — per-round winner/label readbacks and compaction
      re-uploads, one ``extra_syncs`` each.

    ``intervals`` counts driver dispatches — for a device-resident loop
    that is one per ``check_frequency`` steps; for a legacy host loop it
    equals the number of rounds/supersteps.

    ``edge_staging`` records which :func:`prepare_edges` path staged the
    engine's input: ``"device"`` (the DeviceEdges no-host-round-trip
    hand-off) or ``"host"`` (layout built host-side and uploaded).  Empty
    for engines that do not route through ``prepare_edges``.

    ``rounds_per_graph`` is filled by batched drivers (DESIGN.md §8): one
    round/superstep count per input graph, in input order.  Single-graph
    engines leave it empty.

    ``edges_filtered`` / ``filter_passes`` are filled by the Filter-Borůvka
    sampling hybrid (DESIGN.md §10): edges proven non-MSF by the cycle-rule
    connectivity probe and the number of sample→solve→filter passes run.
    Engines without a filter stage leave them 0.

    ``updates_applied`` / ``replacement_probes`` are filled by the
    incremental pass (DESIGN.md §13): structural edge changes actually
    applied by an :func:`repro.core.incremental.apply_updates` batch, and
    the cut-probe candidates — non-tree edges crossing components severed
    by tree-edge deletions, the pool the final solve elects replacement
    edges from.  Solve-from-scratch engines leave them 0.

    Overlap-aware accounting (DESIGN.md §11): ``host_syncs`` and
    ``intervals`` always count CONSUMED readbacks/dispatches, so the
    contract above is pipeline-invariant.  ``overlapped_syncs`` counts the
    readbacks that were consumed while a successor interval was already in
    flight (0 on a sequential loop); ``speculative_intervals`` counts
    trailing dispatches whose scalars were never fetched because
    termination had already been observed (their device work is a provable
    no-op — see interval_loop).  ``comm_bytes`` is the per-shard on-wire
    byte total of the engine's cross-shard reductions under the selected
    ``params.collective`` (0 off-mesh).
    """

    host_syncs: int = 0
    intervals: int = 0
    extra_syncs: int = 0
    edge_staging: str = ""
    rounds_per_graph: tuple = ()
    edges_filtered: int = 0
    filter_passes: int = 0
    updates_applied: int = 0
    replacement_probes: int = 0
    overlapped_syncs: int = 0
    speculative_intervals: int = 0
    comm_bytes: int = 0


def donation(*argnums: int) -> Tuple[int, ...]:
    """``donate_argnums`` for mutated state buffers, or () on backends
    (CPU) that do not implement donation and would only warn."""
    return argnums if jax.default_backend() != "cpu" else ()


def interval_loop(
    state: Any,
    dispatch: Callable[[Any], Tuple[Any, Any]],
    finish: Callable[[Any, Any], Tuple[Any, bool]],
    *,
    stats: EngineStats,
    max_intervals: int,
    fail_msg: str,
    overlap: bool = False,
) -> Any:
    """Drive a device-resident engine to completion.

    ``dispatch(state) -> (state, scalars)`` runs one fused interval on
    device and returns the new state plus the interval's scalar summary
    (any pytree of device scalars — fetched with ONE ``device_get``).
    ``finish(state, host_scalars) -> (state, done)`` interprets the fetched
    values: it raises on error flags, updates engine counters, may mutate
    the state (e.g. compaction re-dispatch), and reports termination.

    The contract is batch-rank-polymorphic: a dispatch may advance a whole
    graph bucket (state with a leading batch axis), in which case per-graph
    done flags must be reduced ON DEVICE to one scalar before they reach
    the summary — the driver still performs exactly one readback per
    interval regardless of batch size (DESIGN.md §8).

    ``overlap=True`` double-buffers the loop (DESIGN.md §11): interval
    k+1 is dispatched from interval k's device state BEFORE k's scalar
    readback is consumed, so the blocking transfer overlaps in-flight
    device work instead of draining the pipeline.  ``finish`` then runs
    one interval "late": it receives interval k's scalars but the state
    AFTER interval k+1.  A pipelined engine must therefore guarantee
    (1) an interval dispatched from a terminated state is a device no-op
    (state fixed point), so the speculative trailing interval cannot
    perturb the result, and (2) any state surgery ``finish`` performs from
    k's scalars (compaction caps, collective caps) stays correct against
    state k+1 — monotone-shrinking censuses give this for free.  Engines
    whose ``finish`` consumes per-interval state it would otherwise lose
    (the legacy host loops' winner bitmaps) must stay sequential.

    Raises ``RuntimeError(fail_msg)`` if ``max_intervals`` elapse without
    ``finish`` signalling done.
    """
    if not overlap:
        for _ in range(max_intervals):
            state, scalars = dispatch(state)
            vals = jax.device_get(scalars)  # the interval's single host sync
            stats.host_syncs += 1
            stats.intervals += 1
            state, done = finish(state, vals)
            if done:
                return state
        raise RuntimeError(fail_msg)

    # One-interval-deep pipeline: `pending` is interval k's un-consumed
    # scalar summary while `state` already holds interval k's output.
    state, pending = dispatch(state)
    for _ in range(max_intervals):
        state, scalars = dispatch(state)     # interval k+1, speculative
        vals = jax.device_get(pending)       # interval k's single host sync
        stats.host_syncs += 1
        stats.intervals += 1
        stats.overlapped_syncs += 1
        state, done = finish(state, vals)
        if done:
            # Interval k terminated, so the in-flight k+1 ran on a fixed
            # point: its state is byte-identical and its scalars are never
            # fetched — no extra host sync.
            stats.speculative_intervals += 1
            return state
        pending = scalars
    raise RuntimeError(fail_msg)


def forest_from_mask(
    graph: Graph,
    mask: np.ndarray,
    *,
    num_components: Optional[int] = None,
) -> ForestResult:
    """Build a :class:`ForestResult` from a canonical edge bitmap.

    ``num_components`` defaults to ``num_vertices - num_tree_edges`` (exact
    for any forest); engines that track fragment labels may pass the label
    census instead.
    """
    mask = np.asarray(mask, dtype=bool)
    ntree = int(mask.sum())
    total = float(graph.weight[mask].sum(dtype=np.float64))
    if num_components is None:
        num_components = graph.num_vertices - ntree
    return ForestResult(
        total_weight=total,
        edge_mask=mask,
        num_components=num_components,
        num_tree_edges=ntree,
    )


def resolve_round_loop(round_loop: str) -> str:
    """Validate the shared ``params.round_loop`` knob."""
    if round_loop not in ROUND_LOOPS:
        raise ValueError(
            f"unknown round_loop {round_loop!r}; options: {ROUND_LOOPS}")
    return round_loop


def resolve_round_kernel(round_kernel: str) -> str:
    """Validate the ``params.round_kernel`` knob (Borůvka round body).

    ``"xla"`` — the per-edge scatter/gather chain (``_one_round``), the
    seed behavior.  ``"pallas"`` — the fused masked min-plus formulation
    backed by the ``kernels/spmv_minplus`` family (DESIGN.md §9); the
    device round loop and the batched path honor it, the legacy host loop
    and the faithful GHS engine ignore it.
    """
    if round_kernel not in ROUND_KERNELS:
        raise ValueError(
            f"unknown round_kernel {round_kernel!r}; options: {ROUND_KERNELS}")
    return round_kernel


def resolve_collective(collective: str) -> str:
    """Validate the ``params.collective`` knob (DESIGN.md §11): ``"pmin"``
    full-width reductions / ``"compressed"`` delta-exchange candidate
    lists (:func:`repro.sharding.collectives.pmin_compressed`)."""
    from repro.sharding import collectives
    return collectives.resolve_collective(collective)


def resolve_interval_pipeline(depth: int) -> int:
    """Validate the ``params.interval_pipeline`` knob: 0 = sequential
    dispatch→readback→decide, 1 = double-buffered intervals."""
    if depth not in INTERVAL_PIPELINES:
        raise ValueError(
            f"interval_pipeline must be one of {INTERVAL_PIPELINES}, "
            f"got {depth!r}")
    return depth


# ---------------------------------------------------------------------------
# Partition layer (DESIGN.md §7) — both engines' single entry for edges
# ---------------------------------------------------------------------------

def as_graph(source) -> Graph:
    """Host :class:`Graph` view of an engine input (Graph or DeviceEdges)."""
    if isinstance(source, Graph):
        return source
    return source.to_graph()


@dataclasses.dataclass
class EdgeBundle:
    """Edge state in engine layout, ready for the round loop.

    ``src``/``dst``/``key`` are device arrays of ``layout.num_slots`` slots
    (edge-sharded under a mesh); ``slot`` carries each slot's own index
    within its shard so tree-edge recording stays a local scatter under ANY
    partition, surviving on-device compaction (the winner bitmap keeps the
    load-time slot layout for the whole run).  ``source`` retains the
    caller's input for lazy host mirroring (ForestResult construction).
    """

    layout: partition_lib.EdgeLayout
    src: Any
    dst: Any
    key: Any
    slot: Any
    num_vertices: int
    num_edges: int
    source: Any
    staging: str = "host"       # which prepare_edges path staged the input:
                                # "device" — DeviceEdges handed over in place
                                # "host"   — host layout built + uploaded

    def graph(self) -> Graph:
        return as_graph(self.source)


def prepare_edges(
    source, partitioner_name: str, mesh, *, chunk: int
) -> EdgeBundle:
    """Stage edges on device under the chosen partitioner.

    * host :class:`Graph` — the partitioner's :class:`EdgeLayout` is built
      host-side, arrays are gathered into slot order and uploaded once.
    * :class:`~repro.core.pipeline.DeviceEdges` + ``block`` partitioner —
      the pipeline's canonical buffers ARE the block layout: they are handed
      to the engine as-is, no edge ever crossing back to host.  (Non-block
      partitioners fall back to the host mirror: their layouts are host
      decisions by design.)

    The taken path is recorded in ``EdgeBundle.staging`` (drivers surface
    it as ``EngineStats.edge_staging``), and a DeviceEdges input that
    CANNOT take the fast path — non-block partitioner, or a capacity not
    divisible by the engine's shard count — emits a ``UserWarning`` naming
    the reason, instead of silently mirroring the edges through host
    memory.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import keys as keys_lib
    from repro.core import pipeline as pipeline_lib

    part = partition_lib.get_partitioner(partitioner_name)
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    edge_sh = NamedSharding(mesh, P("x")) if mesh is not None else None

    def put(a):
        import jax.numpy as jnp
        return (jax.device_put(a, edge_sh) if edge_sh is not None
                else jnp.asarray(a))

    staging = "host"
    if (isinstance(source, pipeline_lib.DeviceEdges)
            and part.name == "block"
            and source.capacity % num_shards == 0):
        staging = "device"
        cap = source.capacity
        block = cap // num_shards
        eid = np.arange(cap, dtype=np.int64)
        eid[source.num_edges:] = -1
        layout = partition_lib.EdgeLayout(num_shards=num_shards,
                                          block=block, eid=eid)
        # device_put re-lays-out to the engine mesh if the pipeline was
        # built on a different one; a no-op placement otherwise.
        src_d, dst_d, key_d = (put(source.src), put(source.dst),
                               put(source.key))
        n, m = source.num_vertices, source.num_edges
    else:
        if isinstance(source, pipeline_lib.DeviceEdges):
            why = (f"partitioner {part.name!r} is a host-side layout "
                   f"decision" if part.name != "block" else
                   f"capacity {source.capacity} is not divisible by "
                   f"num_shards {num_shards}")
            warnings.warn(
                f"DeviceEdges cannot take the no-host-round-trip fast "
                f"path ({why}); falling back to a full host mirror",
                stacklevel=2)
        graph = as_graph(source)
        layout = partition_lib.build_edge_layout(
            graph, part, num_shards, chunk)
        valid = layout.eid >= 0
        gather = layout.eid[valid]
        src_p = np.full(layout.num_slots, PAD_VERTEX, np.int32)
        dst_p = np.full(layout.num_slots, PAD_VERTEX, np.int32)
        key_p = np.full(layout.num_slots, keys_lib.INF_KEY, np.uint64)
        src_p[valid] = graph.src[gather]
        dst_p[valid] = graph.dst[gather]
        key_p[valid] = graph.packed_keys[gather]
        src_d, dst_d, key_d = put(src_p), put(dst_p), put(key_p)
        n, m = graph.num_vertices, graph.num_edges

    slot_np = (np.arange(layout.num_slots, dtype=np.int64)
               % layout.block).astype(np.int32)
    return EdgeBundle(layout=layout, src=src_d, dst=dst_d, key=key_d,
                      slot=put(slot_np), num_vertices=n, num_edges=m,
                      source=source, staging=staging)


def vertex_partitioned(graph: Graph, partitioner_name: str,
                       num_shards: int) -> Graph:
    """Realize a vertex partition for the block-routed GHS engine.

    Returns a relabeled graph whose block distribution equals the
    partitioner's assignment.  Edge order, weights, and canonical edge ids
    are untouched, so the engine's forest (recorded by canonical id) is
    bit-identical to running on the original labels.
    """
    part = partition_lib.get_partitioner(partitioner_name)
    if part.name == "block":
        return graph
    perm = part.vertex_perm(graph, num_shards)
    return partition_lib.relabel_graph(graph, perm)
