"""Shared engine runtime — the interval-driven driver both MST engines use.

DESIGN.md §6.  Both engines (the paper-faithful message GHS and the
synchronous Borůvka reformulation) follow the same execution shape once
their inner loops are device-resident:

    compile a fused *interval* function   (lax.while_loop over N steps)
    loop:  dispatch one interval          (state stays on device)
           read back ONE fused scalar vector
           host decides: done? error? re-bucket/compact?

This module owns the pieces that are engine-independent:

* :func:`interval_loop` — the host driver harness.  Per interval it performs
  exactly one blocking device→host transfer (``jax.device_get`` on the
  dispatch's scalar outputs) and one ``host_syncs``/``intervals`` ledger
  update, then hands the scalars to an engine-specific ``finish`` hook that
  interprets them (raise on error flags, count rounds/supersteps, trigger
  compaction) and decides termination.
* :class:`EngineStats` — the unified stats protocol: every engine's stats
  object derives from it so benchmarks can meter host syncs and interval
  counts uniformly.
* :func:`donation` — ``donate_argnums`` selection: state buffers are donated
  for in-place reuse on backends that implement donation (CPU does not;
  donating there only emits warnings).
* :func:`forest_from_mask` — the shared forest-extraction path from a
  canonical edge bitmap to a :class:`ForestResult`.
* :func:`resolve_round_loop` — validation of the ``params.round_loop`` knob
  shared by both engines (``"device"`` fused loop / ``"host"`` legacy).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

from repro.core.graph import Graph
from repro.core.kruskal_ref import ForestResult

ROUND_LOOPS = ("device", "host")


@dataclasses.dataclass
class EngineStats:
    """Host↔device traffic ledger common to every engine driver.

    ``host_syncs`` counts blocking transfer points (the driver adds one per
    interval; engine hooks add any extras they perform, e.g. the final state
    fetch or a legacy path's winner-bitmap readback).  ``intervals`` counts
    driver dispatches — for a device-resident loop that is one per
    ``check_frequency`` steps; for a legacy host loop it equals the number
    of rounds/supersteps.
    """

    host_syncs: int = 0
    intervals: int = 0


def donation(*argnums: int) -> Tuple[int, ...]:
    """``donate_argnums`` for mutated state buffers, or () on backends
    (CPU) that do not implement donation and would only warn."""
    return argnums if jax.default_backend() != "cpu" else ()


def interval_loop(
    state: Any,
    dispatch: Callable[[Any], Tuple[Any, Any]],
    finish: Callable[[Any, Any], Tuple[Any, bool]],
    *,
    stats: EngineStats,
    max_intervals: int,
    fail_msg: str,
) -> Any:
    """Drive a device-resident engine to completion.

    ``dispatch(state) -> (state, scalars)`` runs one fused interval on
    device and returns the new state plus the interval's scalar summary
    (any pytree of device scalars — fetched with ONE ``device_get``).
    ``finish(state, host_scalars) -> (state, done)`` interprets the fetched
    values: it raises on error flags, updates engine counters, may mutate
    the state (e.g. compaction re-dispatch), and reports termination.

    Raises ``RuntimeError(fail_msg)`` if ``max_intervals`` elapse without
    ``finish`` signalling done.
    """
    for _ in range(max_intervals):
        state, scalars = dispatch(state)
        vals = jax.device_get(scalars)  # the interval's single host sync
        stats.host_syncs += 1
        stats.intervals += 1
        state, done = finish(state, vals)
        if done:
            return state
    raise RuntimeError(fail_msg)


def forest_from_mask(
    graph: Graph,
    mask: np.ndarray,
    *,
    num_components: Optional[int] = None,
) -> ForestResult:
    """Build a :class:`ForestResult` from a canonical edge bitmap.

    ``num_components`` defaults to ``num_vertices - num_tree_edges`` (exact
    for any forest); engines that track fragment labels may pass the label
    census instead.
    """
    mask = np.asarray(mask, dtype=bool)
    ntree = int(mask.sum())
    total = float(graph.weight[mask].sum(dtype=np.float64))
    if num_components is None:
        num_components = graph.num_vertices - ntree
    return ForestResult(
        total_weight=total,
        edge_mask=mask,
        num_components=num_components,
        num_tree_edges=ntree,
    )


def resolve_round_loop(round_loop: str) -> str:
    """Validate the shared ``params.round_loop`` knob."""
    if round_loop not in ROUND_LOOPS:
        raise ValueError(
            f"unknown round_loop {round_loop!r}; options: {ROUND_LOOPS}")
    return round_loop
