"""State containers + host-side initialization for the faithful GHS engine.

Vertices are block-distributed across shards (paper §3: "All graph vertices
are sequentially distributed in blocks among the processes"); each shard holds
the CSR adjacency of its owned vertices (both directions), weight-sorted per
vertex so GHS's "probe Basic edges lightest-first" is a cursor scan.

Message encoding (paper §3.5 / C3): a message is ``LANES`` uint32 words.
Compressed layout (5 lanes = 160 bits ≈ the paper's 152-bit long message):

    [0] hdr  = type(3b) | state(1b) | level(28b)
    [1] src  vertex (global id)
    [2] dst  vertex (global id)
    [3] fw   weight bits   (fragment id / report weight, hi word)
    [4] fe   tiebreak lane (fragment id / report weight, lo word)

Uncompressed ablation layout (8 lanes = 256 bits): one field per lane.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from repro.core import graph as graph_lib
from repro.core.graph import Graph
from repro.core.params import GHSParams

INF32 = np.uint32(0xFFFFFFFF)

# Sentinel for the local-queue position side-lane: the message's edge has not
# been batch-resolved yet; dispatch must run the scalar probe (-1 is reserved
# for a genuine miss, which is an ERR_HASH_MISS).
POS_UNRESOLVED = np.int32(-2)

# Message types (3 bits).
CONNECT, INITIATE, TEST, ACCEPT, REJECT, REPORT, CHANGE_CORE = range(7)
MSG_NAMES = ("Connect", "Initiate", "Test", "Accept", "Reject", "Report",
             "ChangeCore")
# Vertex states.
SLEEPING, FIND, FOUND = 0, 1, 2
# Edge states.
BASIC, BRANCH, REJECTED = 0, 1, 2

# Hash mixing constants (32-bit adaptation of the paper's
# ((u << 32) | v) mod T — see DESIGN.md §2/C2).
HASH_K1 = np.uint32(2654435761)
HASH_K2 = np.uint32(2246822519)


def hash_slot(lv, u, table_size):
    """Identical arithmetic under numpy and jax.numpy (uint32 wraparound)."""
    mixed = (lv.astype(np.uint32) * HASH_K1) ^ (u.astype(np.uint32) * HASH_K2)
    return (mixed % np.uint32(table_size)).astype(np.int32)


class ShardState(NamedTuple):
    """Per-shard GHS state. All arrays have NO leading shard axis here; the
    driver stacks them along axis 0 for shard_map."""

    # --- vertex state (nb,) ---
    sn: np.ndarray          # i32 vertex state
    ln: np.ndarray          # u32 fragment level
    fnw: np.ndarray         # u32 fragment id (weight bits)
    fne: np.ndarray         # u32 fragment id (tiebreak)
    find_count: np.ndarray  # i32
    in_branch: np.ndarray   # i32 CSR position or -1
    best_edge: np.ndarray   # i32 CSR position or -1
    best_w: np.ndarray      # u32
    best_e: np.ndarray      # u32
    test_edge: np.ndarray   # i32 CSR position or -1
    # --- adjacency (static topology) ---
    indptr: np.ndarray      # (nb+1,) i32, weight-sorted windows
    nbr: np.ndarray         # (eb,) i32 global neighbor
    ceid: np.ndarray        # (eb,) i32 canonical edge id
    ewb: np.ndarray         # (eb,) u32 weight bits
    etb: np.ndarray         # (eb,) u32 tiebreak (canonical id)
    byid: np.ndarray        # (eb,) i32 window positions sorted by neighbor id
    se: np.ndarray          # (eb,) i32 edge state (mutable)
    # --- hash table (static) ---
    h_lv: np.ndarray        # (T,) i32 local vertex key (-1 empty)
    h_u: np.ndarray         # (T,) i32 neighbor key
    h_pos: np.ndarray       # (T,) i32 CSR position
    # --- queues (``*_pos`` side-lanes carry the batch-resolved CSR position
    #     of each queued message, or POS_UNRESOLVED) ---
    mq: np.ndarray          # (qcap, lanes) u32 main queue ring
    mq_pos: np.ndarray      # (qcap,) i32 resolved CSR position side-lane
    mq_head: np.ndarray     # i64 scalar
    mq_tail: np.ndarray     # i64
    tq: np.ndarray          # (qcap, lanes) u32 test queue ring
    tq_pos: np.ndarray      # (qcap,) i32
    tq_head: np.ndarray     # i64
    tq_tail: np.ndarray     # i64
    # --- outgoing rings, one per destination shard ---
    og: np.ndarray          # (S, ocap, lanes) u32
    og_head: np.ndarray     # (S,) i64
    og_tail: np.ndarray     # (S,) i64
    # --- inbox (filled by exchange) ---
    inbox: np.ndarray       # (S, xcap, lanes) u32
    in_cnt: np.ndarray      # (S,) i32
    # --- flags / counters ---
    err: np.ndarray         # i32 bitmask (1=queue ovfl, 2=hash miss, 4=logic)
    halted: np.ndarray      # i32 fragments that reported w=best=inf
    n_processed: np.ndarray    # i64 messages popped (incl. repeats)
    n_productive: np.ndarray   # i64 messages that were not postponed
    n_sent_remote: np.ndarray  # i64 messages that crossed shards
    n_sent_local: np.ndarray   # i64 loopback messages
    # --- on-device per-superstep histories (Fig 3/4; capacity 1 unless the
    #     driver asked for history — writes out of range are dropped) ---
    hist_act: np.ndarray    # (hcap,) i32 global activity after superstep k
    hist_sent: np.ndarray   # (hcap,) i32 cumulative remote sends after step k


@dataclasses.dataclass(frozen=True)
class GHSTopology:
    """Static layout info shared by the driver and the superstep builder."""

    num_shards: int
    block: int          # vertices per shard
    nb: int             # == block
    eb: int             # padded adjacency entries per shard
    qcap: int
    ocap: int
    xcap: int           # exchange bucket capacity (paper MAX_MSG_SIZE)
    tsize: int          # hash table slots
    lanes: int          # 5 compressed / 8 uncompressed
    num_vertices: int
    num_edges: int


def encode_messages(
    lanes: int, mtype, level, state, src, dst, fw, fe
) -> np.ndarray:
    """Vectorized numpy encoder (init-time Connect(0) wave)."""
    n = len(np.atleast_1d(src))
    out = np.zeros((n, lanes), dtype=np.uint32)
    if lanes == 5:
        hdr = (np.uint32(mtype) | (np.uint32(state) << np.uint32(3))
               | (np.asarray(level, np.uint32) << np.uint32(4)))
        out[:, 0] = hdr
        out[:, 1] = src
        out[:, 2] = dst
        out[:, 3] = fw
        out[:, 4] = fe
    else:
        out[:, 0] = mtype
        out[:, 1] = level
        out[:, 2] = state
        out[:, 3] = src
        out[:, 4] = dst
        out[:, 5] = fw
        out[:, 6] = fe
    return out


def _build_hash_table(lv: np.ndarray, u: np.ndarray, pos: np.ndarray,
                      tsize: int):
    """Vectorized linear-probe insertion (Knuth 6.4, paper §3.3)."""
    h_lv = np.full(tsize, -1, np.int32)
    h_u = np.full(tsize, -1, np.int32)
    h_pos = np.full(tsize, -1, np.int32)
    idx = hash_slot(lv, u, tsize).astype(np.int32)
    pending = np.arange(lv.shape[0], dtype=np.int32)
    for _probe in range(tsize + 1):
        if pending.size == 0:
            break
        slots = idx[pending]
        empty = h_pos[slots] < 0
        cand = pending[empty]
        cslots = slots[empty]
        # first writer wins per slot this round
        uniq, first = np.unique(cslots, return_index=True)
        winners = cand[first]
        h_lv[uniq] = lv[winners]
        h_u[uniq] = u[winners]
        h_pos[uniq] = pos[winners]
        placed = np.zeros(lv.shape[0], dtype=bool)
        placed[winners] = True
        pending = pending[~placed[pending]]
        idx[pending] = (idx[pending] + 1) % tsize
    else:
        raise RuntimeError("hash table build did not converge")
    return h_lv, h_u, h_pos


def init_shards(
    graph: Graph, num_shards: int, params: GHSParams,
    history_capacity: int = 1,
) -> tuple[GHSTopology, list[ShardState]]:
    """Partition the graph, pre-sort adjacency by weight, build hash tables,
    wake every vertex (spontaneous awakening) and enqueue its Connect(0).

    The per-partition CSR is built with TWO global lexsorts (by (vertex,
    packed weight key) for the probe windows, by (vertex, neighbor id) for
    the binary-search ablation) and sliced per shard — no per-vertex Python
    loops, which dominated init at paper scales.  Packed keys are unique
    per edge, so the sorts have no ties and the result is bit-identical to
    the historical per-vertex ``argsort`` construction.
    """
    n, m = graph.num_vertices, graph.num_edges
    wkey = graph.packed_keys  # uint64 host-side sort key (cached on graph)
    block = -(-n // num_shards)
    lanes = 5 if params.compress_messages else 8
    hcap = max(int(history_capacity), 1)

    # Both-direction adjacency (shared incidence convention — graph.py),
    # globally weight-sorted within each vertex window (paper §3.3 "probe
    # Basic edges lightest-first" for free).
    ends, gnbr, geid = graph_lib.both_direction_arrays(graph)
    gnbr = gnbr.astype(np.int32)
    geid = geid.astype(np.int32)
    order = np.lexsort((wkey[geid], ends))
    ends, gnbr, geid = ends[order], gnbr[order], geid[order]
    gptr = graph_lib.vertex_indptr(ends, n)
    deg = np.diff(gptr)
    # Per-window neighbor-id order (binary-search ablation): one lexsort by
    # (vertex, neighbor id) yields each window's id-sorted positions.
    gbyid = np.lexsort((gnbr, ends)).astype(np.int64)

    # per-shard adjacency sizes
    shard_edges = [
        int(deg[s * block: min(n, (s + 1) * block)].sum())
        for s in range(num_shards)
    ]
    eb = max(max(shard_edges), 1)
    xcap = max(int(params.max_msg_size), 64)
    if params.queue_capacity:
        qcap = int(params.queue_capacity)
    else:
        # Ring capacity bound: one superstep appends at most the full
        # exchange (S·xcap) plus locally generated traffic — dominated by a
        # single vertex's Initiate fan-out (≤ max degree) and the wake-up
        # wave (≤ block).  Sized with a 2-4x margin on each term; queue
        # writes are per-message scatters into the ring, so an oversized
        # ring (the old 4·eb bound was ~10x too big) directly slows every
        # push.  Overflow is detected on device (ERR_QUEUE_OVERFLOW) and
        # raised — never a silent wrong forest; ``queue_capacity``
        # overrides for adversarial graphs.
        dmax = int(deg.max()) if deg.size else 0
        qcap = max(4096, 2 * num_shards * xcap, 4 * dmax, 2 * block)
    ocap = qcap
    tsize = (max(64, int(eb * params.hash_table_factor) | 1)
             if params.use_hashing else 1)

    topo = GHSTopology(
        num_shards=num_shards, block=block, nb=block, eb=eb, qcap=qcap,
        ocap=ocap, xcap=xcap, tsize=tsize, lanes=lanes,
        num_vertices=n, num_edges=graph.num_edges,
    )

    shards = []
    for s in range(num_shards):
        v0, v1 = s * block, min(n, (s + 1) * block)
        nloc = v1 - v0
        # Slice the owned vertices' windows out of the global sorted arrays.
        a0, a1 = int(gptr[v0]), int(gptr[v1])
        mloc = a1 - a0
        nbr = gnbr[a0:a1].astype(np.int32)
        eid = geid[a0:a1].astype(np.int32)
        indptr = np.zeros(block + 1, np.int32)
        indptr[1:nloc + 1] = (gptr[v0 + 1:v1 + 1] - a0).astype(np.int32)
        indptr[nloc + 1:] = indptr[nloc]
        # pad adjacency
        pad = eb - mloc
        nbr = np.concatenate([nbr, np.full(pad, -1, np.int32)])
        eid = np.concatenate([eid, np.zeros(pad, np.int32)])
        if graph.num_edges:
            ewb = graph.weight.view(np.uint32)[eid].copy()
        else:
            ewb = np.full(eb, INF32, np.uint32)
        etb = eid.astype(np.uint32)
        ewb[mloc:] = INF32
        etb[mloc:] = INF32
        # per-window neighbor-id order (binary-search ablation)
        byid = np.arange(eb, dtype=np.int32)
        byid[:mloc] = (gbyid[a0:a1] - a0).astype(np.int32)
        # hash table over (local vertex, neighbor) -> position
        if params.use_hashing:
            owner_lv = np.repeat(np.arange(nloc, dtype=np.int32),
                                 np.diff(indptr[:nloc + 1]))
            h_lv, h_u, h_pos = _build_hash_table(
                owner_lv, nbr[:mloc], np.arange(mloc, dtype=np.int32), tsize)
        else:
            h_lv = np.full(tsize, -1, np.int32)
            h_u = np.full(tsize, -1, np.int32)
            h_pos = np.full(tsize, -1, np.int32)

        se = np.zeros(eb, np.int32)
        sn = np.full(block, FOUND, np.int32)
        ln = np.zeros(block, np.uint32)
        # Spontaneous awakening (vectorized): every non-isolated owned vertex
        # marks its lightest edge Branch (window start — weight-sorted) and
        # queues Connect(0) to that neighbor, in ascending vertex order.
        lvs = np.flatnonzero(np.diff(indptr[:nloc + 1]) > 0).astype(np.int64)
        starts = indptr[lvs]
        se[starts] = BRANCH
        dests = nbr[starts].astype(np.int64)
        wake = encode_messages(lanes, CONNECT, 0, 0,
                               (v0 + lvs).astype(np.uint32),
                               dests.astype(np.uint32), 0, 0) \
            if lvs.size else np.zeros((0, lanes), np.uint32)
        ds_all = dests // block

        mq = np.zeros((qcap, lanes), np.uint32)
        local = ds_all == s
        k = int(local.sum())
        if k > qcap:
            raise RuntimeError(
                f"GHS queue overflow at init: {k} wake-up messages exceed "
                f"queue_capacity={qcap}")
        if k:
            mq[:k] = wake[local]
        og = np.zeros((num_shards, ocap, lanes), np.uint32)
        og_tail = np.zeros(num_shards, np.int32)
        for ds in range(num_shards):
            if ds == s:
                continue
            sel = ds_all == ds
            cnt = int(sel.sum())
            if cnt > ocap:
                raise RuntimeError(
                    f"GHS queue overflow at init: {cnt} wake-up "
                    f"messages exceed queue_capacity={ocap}")
            if cnt:
                og[ds, :cnt] = wake[sel]
                og_tail[ds] = cnt

        shards.append(ShardState(
            sn=sn, ln=ln,
            fnw=np.zeros(block, np.uint32), fne=np.zeros(block, np.uint32),
            find_count=np.zeros(block, np.int32),
            in_branch=np.full(block, -1, np.int32),
            best_edge=np.full(block, -1, np.int32),
            best_w=np.full(block, INF32, np.uint32),
            best_e=np.full(block, INF32, np.uint32),
            test_edge=np.full(block, -1, np.int32),
            indptr=indptr, nbr=nbr, ceid=eid, ewb=ewb, etb=etb, byid=byid,
            se=se, h_lv=h_lv, h_u=h_u, h_pos=h_pos,
            mq=mq, mq_pos=np.full(qcap, POS_UNRESOLVED, np.int32),
            mq_head=np.int32(0), mq_tail=np.int32(k),
            tq=np.zeros((qcap, lanes), np.uint32),
            tq_pos=np.full(qcap, POS_UNRESOLVED, np.int32),
            tq_head=np.int32(0), tq_tail=np.int32(0),
            og=og, og_head=np.zeros(num_shards, np.int32), og_tail=og_tail,
            inbox=np.zeros((num_shards, xcap, lanes), np.uint32),
            in_cnt=np.zeros(num_shards, np.int32),
            err=np.int32(0), halted=np.int32(0),
            n_processed=np.int32(0), n_productive=np.int32(0),
            n_sent_remote=np.int32(0), n_sent_local=np.int32(0),
            hist_act=np.zeros(hcap, np.int32),
            hist_sent=np.zeros(hcap, np.int32),
        ))
    return topo, shards


def stack_shards(shards: list[ShardState]) -> ShardState:
    """Stack per-shard states along a leading axis for shard_map."""
    return ShardState(*[
        np.stack([getattr(sh, f) for sh in shards])
        for f in ShardState._fields
    ])
