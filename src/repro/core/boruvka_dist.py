"""Device-resident synchronous Borůvka/GHS engine (beyond-paper, DESIGN §3-4).

Re-formulates GHS for SPMD hardware: per round, every fragment's minimum
outgoing edge (MOE) is ONE segmented min over packed 64-bit keys
``(weight_bits << 32) | edge_id`` — GHS's ``Test``/``Report`` message waves
collapse into a single scatter-min sweep and a single fused ``pmin``
collective (the two-phase weight + tie-break election of earlier versions is
gone; the packed key resolves both in the same reduction).  Fragment merging
is min-hooking + pointer doubling (the ``Connect``/``Initiate`` waves).  The
paper's point-to-point short-message traffic — which it identifies as its
limiting factor (§4.2) — is off the critical path entirely.

The round loop itself is device-resident: a ``jax.lax.while_loop`` advances
up to ``check_frequency`` rounds per dispatch, accumulating tree edges into
an on-device ``edge_mask`` and testing termination on device, so the host
synchronizes ONCE per compaction interval instead of once (or more) per
round.  Edge compaction is an on-device prefix-sum stream compaction into
power-of-two buckets; edges never round-trip through host memory.

Edges are block-distributed across devices (shard_map over axis ``"x"``);
the fragment-label array ``comp`` is replicated (paper layout: vertices are
block-distributed, but labels are small — int32 per vertex).  The legacy
host-driven loop is retained as ``params.round_loop == "host"`` for the
before/after measurement in ``benchmarks/bench_round_loop.py`` and as an
ablation baseline; both loops are bit-identical to the Kruskal oracle.

For serving many graphs, :func:`minimum_spanning_forests` runs the SAME
round body over a leading batch axis (``jax.vmap`` over shape-bucketed,
padded lanes — DESIGN.md §8): one dispatch and one scalar readback per
interval for a whole bucket, per-interval Borůvka contraction (sort-based
fragment-pair dedup, provably election-invariant), and per-lane forests
bit-identical to the corresponding single-graph solves.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import keys as keys_lib
from repro.core import partition as partition_lib
from repro.core import runtime
from repro.core import union_find
from repro.core.graph import PAD_VERTEX, Graph
from repro.core.kruskal_ref import ForestResult
from repro.core.params import DEFAULT_PARAMS, GHSParams
from repro.sharding import collectives

INF32 = np.uint32(0xFFFFFFFF)
INF_KEY = keys_lib.INF_KEY
_AXIS = "x"


def _pad_pow2(arrs, multiple: int, fill_vals):
    """Pad to the next power-of-two multiple of ``multiple``.

    src/dst are filled with PAD_VERTEX (far out of vertex range — clamped
    gathers make padding edges self-loops by construction, see graph.py),
    keys/weights with their INF sentinel.
    """
    m = arrs[0].shape[0]
    target = multiple
    while target < m:
        target *= 2
    pad = target - m
    return [
        np.concatenate([a, np.full(pad, f, a.dtype)]) if pad else a
        for a, f in zip(arrs, fill_vals)
    ]


# Power-of-two bucket sizing shared with the layout builder.
_pow2ceil = partition_lib.pow2ceil


def _make_pmin(axis_name: Optional[str], collective: str,
               cand_cap: Optional[int], num_shards: int) -> Callable:
    """``pmin(x, default)`` closure for the round bodies.

    Identity off-mesh; full-width ``lax.pmin`` for ``collective="pmin"``
    (or when no candidate cap is in effect); the compressed delta exchange
    (:func:`repro.sharding.collectives.pmin_compressed`, DESIGN.md §11)
    otherwise.  ``default`` is the per-index baseline a shard contributes
    when its local edges did not improve the entry (``INF_KEY`` for MOE
    keys, the identity parent for hook requests) — the compressed path
    ships only the ``x != default`` entries.
    """
    if axis_name is None:
        return lambda x, default=None: x
    if collective != "compressed" or cand_cap is None:
        return lambda x, default=None: jax.lax.pmin(x, axis_name)

    def pmin(x, default):
        return collectives.pmin_compressed(
            x, axis_name, default=default, cap=cand_cap,
            num_shards=num_shards)

    return pmin


@dataclasses.dataclass
class BoruvkaStats(runtime.EngineStats):
    # host_syncs / intervals inherited from the runtime protocol; for the
    # legacy host loop, intervals == rounds (one dispatch per round).
    rounds: int = 0
    compactions: int = 0
    edges_scanned: int = 0          # Σ active (padded) edges per round
    active_history: tuple = ()      # host loop: global active edges per round;
                                    # device loop: MAX per-shard active count
                                    # per interval (the compaction-cap census)
    comm_history: tuple = ()        # device loop: one (mode, cand_cap,
                                    # rounds, bytes) record per consumed
                                    # interval — per-shard on-wire bytes of
                                    # the round collectives under the
                                    # DESIGN.md §11 wire model (mode is the
                                    # executable actually dispatched:
                                    # 'pmin' or 'compressed')


# ---------------------------------------------------------------------------
# Fused device-resident loop (round_loop="device", the default)
# ---------------------------------------------------------------------------

def _one_round(
    comp: jnp.ndarray,
    mask: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    key: jnp.ndarray,
    slot: jnp.ndarray,
    *,
    pmin: Callable,
    use_pallas: bool,
):
    """One Borůvka round: fused MOE election, winner recording, merging.

    Rank-polymorphic by construction: the single-graph interval calls it on
    (n,)/(cap,) state and the batched engine maps it over a leading batch
    axis with ``jax.vmap`` — both run the exact same ops, which is what
    keeps batched lanes bit-identical to single-graph solves.
    """
    n = comp.shape[0]
    cap = mask.shape[0]
    cs = comp[src]          # PAD_VERTEX clamps → padding is a self-loop
    cd = comp[dst]
    alive = (cs != cd) & (key != INF_KEY)
    k = jnp.where(alive, key, INF_KEY)
    # Fused MOE election: ONE segmented min over both endpoints, ONE
    # collective.  The packed key carries the tie-break, so no second
    # (weight-match, edge-id) pass and no second pmin.
    seg = jnp.concatenate([cs, cd]).astype(jnp.int32)
    from repro.kernels.segment_min import ops as segops
    best = segops.segment_min64(
        jnp.concatenate([k, k]), seg, num_segments=n,
        use_pallas=use_pallas)
    best = pmin(best, INF_KEY)
    winners = alive & ((best[cs] == k) | (best[cd] == k))
    # Record wins into the sharded bitmap; an edge's bitmap slot lives on
    # the shard that loaded it (compaction is shard-local), so the
    # scatter is local for every partitioner.
    mask = mask.at[jnp.where(winners, slot, cap)].set(True, mode="drop")
    # Merge: min-hooking + pointer doubling (GHS Connect/Initiate).
    hi = jnp.maximum(cs, cd).astype(jnp.uint32)
    lo = jnp.minimum(cs, cd).astype(jnp.uint32)
    parent = union_find.hook_min(n, hi, lo, winners)
    parent = pmin(parent, jnp.arange(n, dtype=jnp.uint32))
    parent = union_find.pointer_double(parent)
    done = jnp.all(best == INF_KEY)
    return parent[comp], mask, done


def _run_interval(
    comp: jnp.ndarray,
    mask: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    key: jnp.ndarray,
    slot: jnp.ndarray,
    rounds: jnp.ndarray,
    *,
    axis_name: Optional[str],
    use_pallas: bool,
    collective: str = "pmin",
    cand_cap: Optional[int] = None,
    num_shards: int = 1,
):
    """Advance up to ``rounds`` Borůvka rounds entirely on device.

    State per shard: replicated fragment labels ``comp``, the per-slot tree
    bitmap ``mask`` (frozen in the load-time layout of the partitioner —
    slot i on shard s is canonical edge ``layout.eid[s*block + i]``), and
    the (possibly compacted) local edge arrays.  Each edge carries its own
    load-time ``slot`` index, so winner recording is a local scatter under
    ANY partition and survives compaction.  Returns the new state plus a
    replicated (done, rounds-run, max local active count, max local
    candidate count) vector — the ONLY values the host ever reads.

    ``collective``/``cand_cap`` pick the cross-shard reduction (DESIGN.md
    §11): full-width ``lax.pmin`` or the compressed delta exchange with a
    static per-shard candidate cap (the host re-caps per interval from the
    candidate census below).
    """
    pmin = _make_pmin(axis_name, collective, cand_cap, num_shards)

    def one_round(comp, mask):
        return _one_round(comp, mask, src, dst, key, slot,
                          pmin=pmin, use_pallas=use_pallas)

    def cond(c):
        r, _, _, done = c
        return jnp.logical_not(done) & (r < rounds)

    def body(c):
        r, comp, mask, _ = c
        comp, mask, done = one_round(comp, mask)
        return r + 1, comp, mask, done

    r, comp, mask, done = jax.lax.while_loop(
        cond, body, (jnp.int32(0), comp, mask, jnp.bool_(False)))

    # Active-edge census for the host's compaction-bucket choice, plus the
    # candidate census for the compressed-collective cap: distinct
    # fragments touched by local active edges bound every entry a shard
    # can improve in ANY later round (fragments only merge and edges only
    # die, so the count is non-increasing — valid even consumed one
    # interval late under the double-buffered driver).
    active = (comp[src] != comp[dst]) & (key != INF_KEY)
    n_active = active.sum(dtype=jnp.int32)
    n = comp.shape[0]
    seg = jnp.concatenate([comp[src], comp[dst]]).astype(jnp.uint32)
    seg = jnp.where(jnp.concatenate([active, active]), seg, jnp.uint32(n))
    (seg,) = jax.lax.sort((seg,), num_keys=1)
    first = (seg != jnp.uint32(n)) & jnp.concatenate(
        [jnp.ones((1,), bool), seg[1:] != seg[:-1]])
    n_cand = first.sum(dtype=jnp.int32)
    if axis_name:
        n_active = jax.lax.pmax(n_active, axis_name)
        n_cand = jax.lax.pmax(n_cand, axis_name)
    return comp, mask, done, r, n_active, n_cand


def _one_round_fused(
    comp: jnp.ndarray,
    mask: jnp.ndarray,      # (m,) canonical-eid bitmap, replicated
    src: jnp.ndarray,
    dst: jnp.ndarray,
    key: jnp.ndarray,
    csrc: jnp.ndarray,      # (m,) canonical endpoints, replicated
    cdst: jnp.ndarray,
    *,
    pmin: Callable,
    lowering: str,
    sort_bits,
):
    """One Borůvka round as the fused masked min-plus SpMV (DESIGN.md §9).

    ``params.round_kernel == "pallas"``: the election is ONE
    ``spmv_minplus.elect`` call (masked min-plus SpMV — Pallas kernel,
    scatter-free sort lowering, or the scatter oracle, chosen statically),
    and everything after it runs at fragment scale ``n`` instead of edge
    scale ``cap``:

    * the elected ``best[f]`` already NAMES the winning edge (the packed
      key's id lane is the canonical edge id), so winner recording is an
      n-scale scatter into a replicated canonical-eid bitmap — the
      cap-scale ``winners`` recompute + slot scatter of :func:`_one_round`
      disappears, and so does the end-of-solve slot→canonical remap;
    * the merge partner is recovered from the replicated canonical
      endpoint arrays (two n-scale gathers), so hooking is n-scale too;
    * ``best`` is already globally reduced, so the hook requests are
      identical on every shard and :func:`_one_round`'s second collective
      (the parent ``pmin``) is dropped — ONE collective per round;
    * shortcut + relabel fuse into ``spmv_minplus.shortcut_relabel``.

    Election over identical packed keys, identical winner set, identical
    hook pairs (each elected fragment contributes the same (hi, lo) its
    winning edge would), identical pointer doubling — bit-identical to
    :func:`_one_round` by construction, which the adversarial corpus and
    the bench sweep both enforce.
    """
    from repro.kernels.spmv_minplus import ops as spmv_ops
    n = comp.shape[0]
    m = mask.shape[0]
    cs = comp[src]          # PAD_VERTEX clamps → padding is a self-loop
    cd = comp[dst]
    best = spmv_ops.elect(cs, cd, key, num_segments=n, lowering=lowering,
                          sort_bits=sort_bits)
    best = pmin(best, INF_KEY)
    elected = best != INF_KEY
    eid = keys_lib.unpack_edge_id(best)      # 0xFFFFFFFF when not elected
    mask = mask.at[jnp.where(elected, eid, jnp.uint32(m))].set(
        True, mode="drop")
    u = csrc[eid]           # clamped gathers; garbage gated by ``elected``
    v = cdst[eid]
    cu = comp[u]
    cv = comp[v]
    f = jnp.arange(n, dtype=jnp.uint32)
    other = jnp.where(cu == f, cv, cu)
    hi = jnp.maximum(f, other)
    lo = jnp.minimum(f, other)
    parent = union_find.hook_min(n, hi, lo, elected)
    comp = spmv_ops.shortcut_relabel(parent, comp,
                                     use_pallas=(lowering == "pallas"))
    done = jnp.all(best == INF_KEY)
    return comp, mask, done


def _run_interval_fused(
    comp: jnp.ndarray,
    mask: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    key: jnp.ndarray,
    csrc: jnp.ndarray,
    cdst: jnp.ndarray,
    rounds: jnp.ndarray,
    *,
    axis_name: Optional[str],
    lowering: str,
    sort_bits,
    collective: str = "pmin",
    cand_cap: Optional[int] = None,
    num_shards: int = 1,
):
    """:func:`_run_interval` with the fused round body (round_kernel="pallas").

    Differences from the XLA interval: the tree bitmap is canonical-eid
    indexed and REPLICATED (every shard derives the same writes from the
    globally-reduced election, so no slot side-lane and no final remap),
    and the per-edge ``slot`` array is not consumed — compaction still
    threads it through the engine state for shape uniformity.  The round's
    single collective routes through the same ``collective``/``cand_cap``
    selection as the XLA interval (hooking needs the globally-reduced
    ``best``, and the compressed exchange returns exactly that).
    """
    pmin = _make_pmin(axis_name, collective, cand_cap, num_shards)

    def one_round(comp, mask):
        return _one_round_fused(comp, mask, src, dst, key, csrc, cdst,
                                pmin=pmin, lowering=lowering,
                                sort_bits=sort_bits)

    def cond(c):
        r, _, _, done = c
        return jnp.logical_not(done) & (r < rounds)

    def body(c):
        r, comp, mask, _ = c
        comp, mask, done = one_round(comp, mask)
        return r + 1, comp, mask, done

    r, comp, mask, done = jax.lax.while_loop(
        cond, body, (jnp.int32(0), comp, mask, jnp.bool_(False)))

    # Same censuses as _run_interval (active for compaction, distinct
    # touched fragments for the compressed-collective cap).
    active = (comp[src] != comp[dst]) & (key != INF_KEY)
    n_active = active.sum(dtype=jnp.int32)
    n = comp.shape[0]
    seg = jnp.concatenate([comp[src], comp[dst]]).astype(jnp.uint32)
    seg = jnp.where(jnp.concatenate([active, active]), seg, jnp.uint32(n))
    (seg,) = jax.lax.sort((seg,), num_keys=1)
    first = (seg != jnp.uint32(n)) & jnp.concatenate(
        [jnp.ones((1,), bool), seg[1:] != seg[:-1]])
    n_cand = first.sum(dtype=jnp.int32)
    if axis_name:
        n_active = jax.lax.pmax(n_active, axis_name)
        n_cand = jax.lax.pmax(n_cand, axis_name)
    return comp, mask, done, r, n_active, n_cand


@functools.lru_cache(maxsize=64)
def _build_interval_fn_fused(
        mesh: Optional[Mesh], lowering: str, sort_bits,
        collective: str = "pmin",
        cand_cap: Optional[int] = None) -> Callable:
    # cand_cap is part of the cache key: compressed caps are power-of-two
    # and shrink monotonically with the census, so at most log2(n) variants
    # compile per solve (same budget as the compaction buckets).
    donate = runtime.donation(0, 1)
    if mesh is None:
        fn = partial(_run_interval_fused, axis_name=None, lowering=lowering,
                     sort_bits=sort_bits)
        return jax.jit(fn, donate_argnums=donate)
    num_shards = int(np.prod(mesh.devices.shape))
    fn = compat.shard_map(
        partial(_run_interval_fused, axis_name=_AXIS, lowering=lowering,
                sort_bits=sort_bits, collective=collective,
                cand_cap=cand_cap, num_shards=num_shards),
        mesh,
        # mask + canonical endpoints replicated (see _run_interval_fused);
        # only the edge working set is sharded.
        in_specs=(P(), P(), P(_AXIS), P(_AXIS), P(_AXIS), P(), P(), P()),
        out_specs=(P(), P(), P(), P(), P(), P()),
    )
    return jax.jit(fn, donate_argnums=donate)


_PAD_SLOT = np.int32(0x7FFF0000)   # out of any mask range → scatter-dropped


def _compact_shard(comp, src, dst, key, slot, *, cap: int):
    """Prefix-sum stream compaction of the local edge block to ``cap`` slots.

    Runs entirely on device — dead edges (endpoints in the same fragment)
    are dropped, survivors slide to the front (carrying their load-time
    bitmap ``slot``), the tail refills with the inert padding sentinels.
    ``cap`` is static (a power-of-two bucket), so shapes stay rectangular
    across shards.
    """
    keep = (comp[src] != comp[dst]) & (key != INF_KEY)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    idx = jnp.where(keep, pos, cap)
    new_src = jnp.full((cap,), PAD_VERTEX, jnp.int32).at[idx].set(
        src, mode="drop")
    new_dst = jnp.full((cap,), PAD_VERTEX, jnp.int32).at[idx].set(
        dst, mode="drop")
    new_key = jnp.full((cap,), INF_KEY, jnp.uint64).at[idx].set(
        key, mode="drop")
    new_slot = jnp.full((cap,), _PAD_SLOT, jnp.int32).at[idx].set(
        slot, mode="drop")
    return new_src, new_dst, new_key, new_slot


@functools.lru_cache(maxsize=64)
def _build_interval_fn(mesh: Optional[Mesh], use_pallas: bool,
                       collective: str = "pmin",
                       cand_cap: Optional[int] = None) -> Callable:
    # rounds is a traced scalar, so one executable serves every interval
    # length and graph size per (mesh, shapes).  comp/mask are the mutated
    # state — donate so device buffers are reused in place.  cand_cap is
    # static (see _build_interval_fn_fused for the recompile budget).
    donate = runtime.donation(0, 1)
    if mesh is None:
        fn = partial(_run_interval, axis_name=None, use_pallas=use_pallas)
        return jax.jit(fn, donate_argnums=donate)
    num_shards = int(np.prod(mesh.devices.shape))
    fn = compat.shard_map(
        partial(_run_interval, axis_name=_AXIS, use_pallas=use_pallas,
                collective=collective, cand_cap=cand_cap,
                num_shards=num_shards),
        mesh,
        in_specs=(P(), P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS),
                  P()),
        out_specs=(P(), P(_AXIS), P(), P(), P(), P()),
    )
    return jax.jit(fn, donate_argnums=donate)


@functools.lru_cache(maxsize=64)
def _build_compact_fn(mesh: Optional[Mesh], cap: int) -> Callable:
    # No donation here: compaction is shrink-only, so the inputs are strictly
    # larger than the outputs and could never alias them anyway.
    if mesh is None:
        return jax.jit(partial(_compact_shard, cap=cap))
    fn = compat.shard_map(
        partial(_compact_shard, cap=cap), mesh,
        in_specs=(P(), P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS)),
        out_specs=(P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS)),
    )
    return jax.jit(fn)


def _device_engine(
    source,
    params: GHSParams,
    mesh: Optional[Mesh],
    max_rounds: Optional[int],
) -> tuple[ForestResult, BoruvkaStats]:
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    chunk = max(8 * num_shards, num_shards)

    if isinstance(source, Graph):
        # Host-built weights may be arbitrary; the pipeline's are (0, 1) by
        # construction, so only the host path needs the sentinel check.
        if np.any(source.weight.view(np.uint32) == INF32):
            raise ValueError("weights collide with the INF sentinel")

    with enable_x64():
        bundle = runtime.prepare_edges(
            source, params.partitioner, mesh, chunk=chunk)
        n, m = bundle.num_vertices, bundle.num_edges
        layout = bundle.layout
        m0 = layout.num_slots

        edge_sh = NamedSharding(mesh, P(_AXIS)) if mesh is not None else None
        repl_sh = NamedSharding(mesh, P()) if mesh is not None else None

        def put(a, sh):
            return jax.device_put(a, sh) if sh is not None else jnp.asarray(a)

        src_d, dst_d, key_d, slot_d = (bundle.src, bundle.dst, bundle.key,
                                       bundle.slot)
        comp_dev = put(np.arange(n, dtype=np.uint32), repl_sh)

        fused = runtime.resolve_round_kernel(params.round_kernel) == "pallas"
        if fused:
            # Fused round body (DESIGN.md §9): replicated canonical-eid
            # bitmap + replicated canonical endpoints for n-scale winner
            # recording and hooking.  bundle.graph() is the same host
            # mirror forest_from_mask reads at the end, so this stages no
            # transfer the solve would not have made anyway.
            from repro.kernels.spmv_minplus import ops as spmv_ops
            g_host = bundle.graph()
            csrc_d = put(g_host.src if m else np.zeros(1, np.int32), repl_sh)
            cdst_d = put(g_host.dst if m else np.zeros(1, np.int32), repl_sh)
            mask_dev = put(np.zeros(m, dtype=bool), repl_sh)
            sort_bits = spmv_ops.sort_gate(n, m)
            if sort_bits is not None and np.any(
                    g_host.weight.view(np.uint32)
                    >= spmv_ops.WEIGHT_LIMIT_BITS):
                sort_bits = None   # host weights outside (0, 1): no sort key
            lowering = ("pallas" if params.use_pallas
                        else "sort" if sort_bits is not None else "scatter")
            sb = sort_bits if lowering == "sort" else None
            fn_pmin = _build_interval_fn_fused(mesh, lowering, sb)
        else:
            lowering = sb = None
            mask_dev = put(np.zeros(m0, dtype=bool), edge_sh)
            fn_pmin = _build_interval_fn(mesh, params.use_pallas)

        collective = runtime.resolve_collective(params.collective)
        overlap = (runtime.resolve_interval_pipeline(
            params.interval_pipeline) == 1)
        interval = max(params.check_frequency, 1)
        cap_rounds = max_rounds or (n + 2)
        stats = BoruvkaStats()
        stats.edge_staging = bundle.staging
        history = []
        comm_hist = []
        # Value lanes of the per-round reductions, for the §11 wire model:
        # xla rounds exchange best (uint64) + hook parents (uint32); fused
        # rounds have ONE collective, best only.
        value_bytes = (8,) if fused else (8, 4)
        # cand_bound: upper bound on any shard's per-round candidate count
        # for the NEXT dispatch — refreshed from the interval's
        # distinct-touched-fragments census, which is non-increasing
        # across rounds, so it stays a valid bound even when finish runs
        # one interval late (overlap).  Pre-census bound: each local edge
        # touches at most two fragments.
        box = dict(cur_block=layout.block, dispatched=0, inflight=[],
                   cand_bound=min(n, 2 * layout.block))

        def pick_fn():
            """Select the next dispatch's interval executable + §11 byte
            model: the compressed delta exchange with the census-derived
            candidate cap when its wire model beats full-width pmin, the
            dense pmin executable otherwise (bit-identical either way)."""
            full_b = sum(collectives.dense_bytes(n, num_shards, vb)
                         for vb in value_bytes)
            if num_shards > 1 and collective == "compressed":
                cand_cap = max(_pow2ceil(box["cand_bound"]), 8)
                comp_b = sum(
                    collectives.compressed_bytes(cand_cap, num_shards, vb)
                    for vb in value_bytes)
                if comp_b < full_b:
                    f = (_build_interval_fn_fused(
                            mesh, lowering, sb, "compressed", cand_cap)
                         if fused else
                         _build_interval_fn(mesh, params.use_pallas,
                                            "compressed", cand_cap))
                    return f, "compressed", cand_cap, comp_b
            return fn_pmin, "pmin", 0, full_b

        def dispatch(s):
            comp_dev, mask_dev, src_d, dst_d, key_d, slot_d = s
            # Clamp by the DISPATCHED total, not stats.rounds: under
            # overlap a dispatch happens before the previous interval's
            # readback is consumed.
            this_rounds = max(min(interval, cap_rounds - box["dispatched"]),
                              0)
            f, mode, cand_cap, bytes_per_round = pick_fn()
            if fused:
                comp_dev, mask_dev, done_t, r_t, act_t, cand_t = f(
                    comp_dev, mask_dev, src_d, dst_d, key_d, csrc_d, cdst_d,
                    this_rounds)
            else:
                comp_dev, mask_dev, done_t, r_t, act_t, cand_t = f(
                    comp_dev, mask_dev, src_d, dst_d, key_d, slot_d,
                    this_rounds)
            box["dispatched"] += this_rounds
            # FIFO of per-dispatch ledger records; finish pops the OLDEST
            # (it may run one interval late under overlap) and scales by
            # the rounds the interval actually ran.
            box["inflight"].append(
                (mode, cand_cap, box["cur_block"], bytes_per_round))
            # The interval's scalar summary: four replicated values,
            # fetched by the runtime with ONE device_get.
            return (comp_dev, mask_dev, src_d, dst_d, key_d, slot_d), \
                (done_t, r_t, act_t, cand_t)

        def finish(s, vals):
            done_v, r, n_act, n_cand = vals
            mode, cand_cap, blk, bytes_per_round = box["inflight"].pop(0)
            r = int(r)
            stats.rounds += r
            stats.edges_scanned += r * blk * num_shards
            stats.comm_bytes += r * bytes_per_round
            comm_hist.append((mode, cand_cap, r, r * bytes_per_round))
            history.append(int(n_act))
            box["cand_bound"] = max(min(n, int(n_cand)), 1)
            if bool(done_v):
                return s, True
            if params.compaction == "pow2":
                new_block = max(_pow2ceil(int(n_act)), 8)
                if new_block < box["cur_block"]:   # shrink: ≤ log2 recompiles
                    cfn = _build_compact_fn(mesh, new_block)
                    comp_dev, mask_dev, src_d, dst_d, key_d, slot_d = s
                    src_d, dst_d, key_d, slot_d = cfn(
                        comp_dev, src_d, dst_d, key_d, slot_d)
                    s = (comp_dev, mask_dev, src_d, dst_d, key_d, slot_d)
                    box["cur_block"] = new_block
                    stats.compactions += 1
            return s, False

        comp_dev, mask_dev = runtime.interval_loop(
            (comp_dev, mask_dev, src_d, dst_d, key_d, slot_d), dispatch,
            finish, stats=stats, max_intervals=cap_rounds,
            fail_msg="Borůvka engine failed to converge",
            overlap=overlap)[:2]

        comp_final, mask_full = jax.device_get((comp_dev, mask_dev))
        stats.host_syncs += 1          # final state fetch
        stats.extra_syncs += 1

    comp_final = np.asarray(comp_final)
    if fused:
        # The fused rounds record winners canonical-eid-indexed directly.
        mask = np.asarray(mask_full)
    else:
        # The bitmap lives in the load-time slot layout; the layout maps
        # slots back to canonical edge ids (padding slots never set).
        mask = layout.canonical_mask(np.asarray(mask_full), m)
    ncomp = int(np.unique(comp_final).size)
    res = runtime.forest_from_mask(bundle.graph(), mask, num_components=ncomp)
    res.check_consistent(n)
    stats.active_history = tuple(history)
    stats.comm_history = tuple(comm_hist)
    return res, stats


# ---------------------------------------------------------------------------
# Batched multi-graph engine (DESIGN.md §8): the same round loop under vmap
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchStats(BoruvkaStats):
    """Stats for a batched solve.  ``rounds_per_graph`` (inherited from the
    runtime protocol) is ordered like the input sequence; ``bucket_shapes``
    records one ``(n_pad, cap, batch_size)`` triple per dispatched bucket.

    :meth:`merge` is also the accumulation base of
    :class:`repro.core.filter_boruvka.FilterStats` — the filter driver sums
    its sample/final sub-solves' ledgers through the same path."""

    buckets: int = 0
    bucket_shapes: tuple = ()

    def merge(self, st: BoruvkaStats) -> None:
        """Accumulate a sub-solve's ledger (one bucket, or one single-graph
        fallback run) — the ONE place the shared counters are summed."""
        self.host_syncs += st.host_syncs
        self.intervals += st.intervals
        self.extra_syncs += st.extra_syncs
        self.rounds += st.rounds
        self.compactions += st.compactions
        self.edges_scanned += st.edges_scanned
        self.active_history += st.active_history
        self.overlapped_syncs += st.overlapped_syncs
        self.speculative_intervals += st.speculative_intervals
        self.comm_bytes += st.comm_bytes
        self.comm_history += st.comm_history


def _one_round_packed(comp, mask, src, dst, key, slot, *,
                      s_bits: int, c_bits: int, election: str = "scatter"):
    """One Borůvka round specialized to the batched identity layout.

    Bit-identical to :func:`_one_round` (same elections, same winner set,
    same merges) but cheaper on scatter-bound backends: the election value
    packs (weight-bits ‖ edge-id ‖ other-endpoint-fragment) into one uint64
    — appending the other fragment BELOW the unique edge id cannot change
    the (weight, id) total order — so the elected ``best[f]`` already names
    the winning edge's bitmap slot (slot == canonical id in this layout)
    AND the fragment to merge with.  Winner recording and min-hooking then
    scatter ``n_pad`` per-fragment requests instead of ``cap`` per-edge
    ones; the only cap-scale scatter left is the election itself.
    """
    n = comp.shape[0]
    cap = mask.shape[0]
    ones = jnp.uint64(0xFFFFFFFFFFFFFFFF)
    cs = comp[src]          # PAD_VERTEX clamps → padding is a self-loop
    cd = comp[dst]
    alive = (cs != cd) & (key != INF_KEY)
    wbits = key >> jnp.uint64(32)
    eid = key & jnp.uint64(0xFFFFFFFF)
    base = ((wbits << jnp.uint64(c_bits + s_bits))
            | (eid << jnp.uint64(s_bits)))
    if election == "sort":
        # round_kernel="pallas", batched: the same masked min-plus election
        # lowered scatter-free — prepend the electing fragment ABOVE the
        # packed value (weights < 1.0 keep wbits in 30 bits, so
        # (seg ‖ wbits ‖ eid ‖ other) is 2·s_bits + 30 + c_bits ≤ 64, the
        # contraction gate), key-only sort, and read each fragment's
        # winner back with a searchsorted probe.  Exact min over identical
        # values → bit-identical to the scatter election.
        shift = jnp.uint64(30 + c_bits + s_bits)
        sk = jnp.concatenate([
            jnp.where(alive, (cs.astype(jnp.uint64) << shift)
                      | base | cd.astype(jnp.uint64), ones),
            jnp.where(alive, (cd.astype(jnp.uint64) << shift)
                      | base | cs.astype(jnp.uint64), ones),
        ])
        (sk,) = jax.lax.sort((sk,), num_keys=1)
        m2 = sk.shape[0]
        frag = jnp.arange(n, dtype=jnp.uint64)
        pos = jnp.searchsorted(sk, frag << shift)
        cand = sk[jnp.minimum(pos, m2 - 1)]
        found = (pos < m2) & ((cand >> shift) == frag) & (cand != ones)
        best = jnp.where(
            found, cand & ((jnp.uint64(1) << shift) - jnp.uint64(1)), ones)
    else:
        seg = jnp.concatenate([cs, cd]).astype(jnp.int32)
        val = jnp.concatenate([
            jnp.where(alive, base | cd.astype(jnp.uint64), ones),
            jnp.where(alive, base | cs.astype(jnp.uint64), ones),
        ])
        best = jnp.full((n,), ones, jnp.uint64).at[seg].min(val, mode="drop")
    elected = best != ones
    best_eid = ((best >> jnp.uint64(s_bits))
                & jnp.uint64((1 << c_bits) - 1)).astype(jnp.int32)
    other = (best & jnp.uint64((1 << s_bits) - 1)).astype(jnp.uint32)
    mask = mask.at[jnp.where(elected, best_eid, cap)].set(True, mode="drop")
    f = jnp.arange(n, dtype=jnp.uint32)
    hi = jnp.maximum(f, other)
    lo = jnp.minimum(f, other)
    parent = union_find.hook_min(n, hi, lo, elected)
    parent = union_find.pointer_double(parent)
    done = jnp.all(best == ones)
    return parent[comp], mask, done


def _contract_lane(comp, src, dst, key, *, s_bits: int, c_bits: int):
    """Borůvka contraction of one batch lane — sort-based, scatter-free.

    Endpoints are rewritten to their fragment labels and parallel
    cross-fragment edges collapse to the min-key edge per fragment pair.
    This cannot change any future election: a dropped edge shares its
    fragment pair with a strictly smaller key, so it can never be ANY
    fragment's minimum outgoing edge (and ``done`` still flips exactly when
    no fragment has an outgoing edge).  Forests stay bit-identical.

    The whole (lo-fragment, hi-fragment, weight-bits, edge-id) quadruple
    packs into ONE uint64 — fragment labels fit ``s_bits`` each, (0, 2)
    weights have zero sign/exponent-MSB so their IEEE bits fit 30, and in
    the batched identity layout the canonical edge id doubles as the bitmap
    slot and fits ``c_bits`` — so contraction is two *key-only* sorts (pair
    grouping, then survivors-to-front), the cheap primitive on XLA:CPU
    (DESIGN.md §7), instead of the per-element scatters that dominate the
    round loop at serving scales.  Every field unpacks from the sorted key.
    """
    ones = jnp.uint64(0xFFFFFFFFFFFFFFFF)
    cu = comp[src]          # PAD_VERTEX clamps → padding stays a self-loop
    cd = comp[dst]
    alive = (cu != cd) & (key != INF_KEY)
    lo = jnp.minimum(cu, cd).astype(jnp.uint64)
    hi = jnp.maximum(cu, cd).astype(jnp.uint64)
    wbits = key >> jnp.uint64(32)
    eid = key & jnp.uint64(0xFFFFFFFF)
    packed = ((lo << jnp.uint64(c_bits + 30 + s_bits))
              | (hi << jnp.uint64(c_bits + 30))
              | (wbits << jnp.uint64(c_bits))
              | eid)
    packed = jnp.where(alive, packed, ones)
    (packed,) = jax.lax.sort((packed,), num_keys=1)
    pair = packed >> jnp.uint64(c_bits + 30)
    valid = packed != ones
    first = valid & jnp.concatenate(
        [jnp.ones((1,), bool), pair[1:] != pair[:-1]])
    count = first.sum(dtype=jnp.int32)
    (kept,) = jax.lax.sort((jnp.where(first, packed, ones),), num_keys=1)
    dead = kept == ones
    eid2 = kept & jnp.uint64((1 << c_bits) - 1)
    wb2 = (kept >> jnp.uint64(c_bits)) & jnp.uint64((1 << 30) - 1)
    hi2 = (kept >> jnp.uint64(c_bits + 30)) & jnp.uint64((1 << s_bits) - 1)
    lo2 = kept >> jnp.uint64(c_bits + 30 + s_bits)
    new_src = jnp.where(dead, PAD_VERTEX, lo2.astype(jnp.int32))
    new_dst = jnp.where(dead, PAD_VERTEX, hi2.astype(jnp.int32))
    new_key = jnp.where(dead, INF_KEY, (wb2 << jnp.uint64(32)) | eid2)
    new_slot = jnp.where(dead, _PAD_SLOT, eid2.astype(jnp.int32))
    return new_src, new_dst, new_key, new_slot, count


def _run_interval_batch(
    comp: jnp.ndarray,      # (B, n_pad) uint32
    mask: jnp.ndarray,      # (B, cap) bool
    src: jnp.ndarray,       # (B, cap) int32
    dst: jnp.ndarray,
    key: jnp.ndarray,       # (B, cap) uint64
    slot: jnp.ndarray,      # (B, cap) int32
    done: jnp.ndarray,      # (B,) bool
    rdone: jnp.ndarray,     # (B,) int32 — per-graph rounds run so far
    rounds: jnp.ndarray,
    *,
    use_pallas: bool,
    contract_bits: Optional[Tuple[int, int]],
    election: str = "scatter",
):
    """Advance up to ``rounds`` Borůvka rounds for a whole graph bucket.

    Every round maps :func:`_one_round` over the leading batch axis; lanes
    whose graph has converged are frozen (their ``done`` flag gates the
    carry update), so each lane's comp/mask/rounds trajectory is exactly the
    single-graph engine's.  Termination for the host is the per-graph done
    vector reduced to ONE replicated scalar (``all_done``) — the interval's
    single readback, per the runtime contract.

    When ``contract_bits = (s_bits, c_bits)`` rounds run the packed-key
    variant (:func:`_one_round_packed`) and the interval ends with a fused
    per-lane :func:`_contract_lane` (in place, same capacity); the returned
    census is then the max DEDUPED edge count, so the host's next shrink
    needs no extra readback.  Without it, rounds are plain
    :func:`_one_round` and the census counts active slots (the fallback for
    buckets whose packing doesn't fit 64 bits).
    """
    if contract_bits is not None:
        s_bits, c_bits = contract_bits
        step = jax.vmap(partial(_one_round_packed, s_bits=s_bits,
                                c_bits=c_bits, election=election))
    else:
        step = jax.vmap(partial(_one_round, pmin=lambda x, default=None: x,
                                use_pallas=use_pallas))

    def cond(c):
        r, _, _, done, _ = c
        return jnp.logical_not(jnp.all(done)) & (r < rounds)

    def body(c):
        r, comp, mask, done, rdone = c
        comp2, mask2, done2 = step(comp, mask, src, dst, key, slot)
        live = jnp.logical_not(done)
        comp = jnp.where(live[:, None], comp2, comp)
        mask = jnp.where(live[:, None], mask2, mask)
        rdone = rdone + live.astype(jnp.int32)
        done = done | done2
        return r + 1, comp, mask, done, rdone

    r, comp, mask, done, rdone = jax.lax.while_loop(
        cond, body, (jnp.int32(0), comp, mask, done, rdone))

    if contract_bits is not None:
        s_bits, c_bits = contract_bits
        src, dst, key, slot, counts = jax.vmap(
            partial(_contract_lane, s_bits=s_bits, c_bits=c_bits))(
                comp, src, dst, key)
        census = counts.max()
    else:
        # Active-edge census (max over lanes) for the compaction cap.
        census = jax.vmap(
            lambda c, s, d, k: ((c[s] != c[d]) & (k != INF_KEY)).sum(
                dtype=jnp.int32))(comp, src, dst, key).max()
    return (comp, mask, src, dst, key, slot, done, rdone,
            jnp.all(done), r, census)


@functools.lru_cache(maxsize=256)
def _build_batch_interval_fn(
        use_pallas: bool, contract_bits: Optional[Tuple[int, int]],
        election: str = "scatter") -> Callable:
    # The whole per-lane state is mutated (contraction rewrites the edge
    # arrays too) — donate it all for in-place reuse; rounds is traced, so
    # one executable serves every interval length per bucket shape.
    # contract_bits is per (n_pad, cap) bucket shape, so the cache must
    # hold the serving lattice's full combo set: evicting an entry
    # destroys the jit object AND every executable warmup compiled
    # through it, re-paying those compiles mid-request (a 256-vertex
    # lattice has ~60 combos — the old maxsize=16 silently discarded
    # most of the §12 warmup).
    donate = runtime.donation(0, 1, 2, 3, 4, 5, 6, 7)
    fn = partial(_run_interval_batch, use_pallas=use_pallas,
                 contract_bits=contract_bits, election=election)
    return jax.jit(fn, donate_argnums=donate)


@functools.lru_cache(maxsize=64)
def _build_batch_shrink_fn(cap: int) -> Callable:
    """Slice every lane's (contracted, front-packed) edge arrays down to
    ``cap`` slots — a static-shape copy, no readback needed."""
    return jax.jit(lambda src, dst, key, slot: (
        src[:, :cap], dst[:, :cap], key[:, :cap], slot[:, :cap]))


@functools.lru_cache(maxsize=64)
def _build_batch_compact_fn(cap: int) -> Callable:
    """Per-lane stream compaction of a bucket to ``cap`` slots (vmapped
    :func:`_compact_shard` — survivors keep their load-time ``slot``, so
    winner recording stays a local scatter under the batch axis too)."""
    return jax.jit(jax.vmap(partial(_compact_shard, cap=cap)))


def warm_bucket(
    batch_size: int,
    n_pad: int,
    cap: int,
    params: GHSParams = DEFAULT_PARAMS,
) -> int:
    """Precompile EVERY executable a ``(batch_size, n_pad, cap)`` bucket
    can touch during a solve (DESIGN.md §12 warmup): the vmapped interval
    fn at the load cap AND at every pow2 compaction cap below it, plus the
    shrink slices between those caps.

    Solving an all-ghost flush only compiles the load-cap trace — ghost
    lanes converge before ever compacting, so without this the FIRST real
    flush of a shape pays the post-shrink retraces mid-request, exactly
    the latency spike warmup exists to prevent.  Under a bounded lattice
    the widened uniform contraction bits (:func:`_lattice_contract_bits`)
    make every cap share one fn object, so a larger cap's ladder covers
    the smaller caps' load traces and re-warms are cache hits; without
    bounds the fn cache key carries the ORIGINAL bucket's bits and the
    sub-cap traces are per-cap.  Mirrors ``_solve_bucket``'s static-key
    computation on an empty batch: the contraction gate and election
    lowering are data-independent for (0, 1)-weight traffic.  Only the
    contracted
    front-packed shrink path is warmed (the plain per-lane compact path
    runs only when the bit-gate fails, which pipeline weights never
    trigger).  Returns the number of executables compiled."""
    B = int(batch_size)
    s_bits = max(n_pad - 1, 1).bit_length()
    c_bits = max(cap - 1, 1).bit_length()
    contract_bits = ((s_bits, c_bits)
                     if params.compaction == "pow2"
                     and 2 * s_bits + 30 + c_bits <= 64 else None)
    # Mirror _solve_bucket's widening so the fn object warmed here IS the
    # runtime fn object (and sub-cap traces are shared across caps).
    contract_bits = _widen_contract_bits(contract_bits, params)
    election = "scatter"
    if (runtime.resolve_round_kernel(params.round_kernel) == "pallas"
            and contract_bits is not None):
        election = "sort"
    fn = _build_batch_interval_fn(params.use_pallas, contract_bits,
                                  election)

    # The load cap itself plus every pow2 compaction target below it
    # (``finish`` only ever shrinks to ``max(pow2ceil(census), 8)``).
    # A run-to-completion interval (>= the n_pad + 2 round bound, the §12
    # dispatch policy) converges every lane inside the FIRST dispatch, so
    # the shrink ladder can never run — warming it would compile
    # executables the runtime cannot reach.
    caps = [cap]
    if params.batch_check_frequency < n_pad + 2:
        c = 8
        while c * 2 < cap:
            c *= 2
        while c >= 8 and c < cap:
            caps.append(c)
            c //= 2
    compiled = 0
    with enable_x64():
        for cur in caps:
            # Fresh state every iteration: the interval fn donates all
            # eight state buffers, so nothing it consumed may be reused.
            comp = jnp.asarray(
                np.broadcast_to(np.arange(n_pad, dtype=np.uint32),
                                (B, n_pad)).copy())
            mask = jnp.zeros((B, cap), bool)
            done = jnp.zeros((B,), bool)
            rdone = jnp.zeros((B,), jnp.int32)
            src = jnp.full((B, cur), PAD_VERTEX, jnp.int32)
            dst = jnp.full((B, cur), PAD_VERTEX, jnp.int32)
            key = jnp.full((B, cur), INF_KEY, jnp.uint64)
            slot = jnp.asarray(partition_lib.batched_slots(B, cur))
            state = fn(comp, mask, src, dst, key, slot, done, rdone, 1)
            jax.block_until_ready(state)
            compiled += 1
            _, _, src_o, dst_o, key_o, slot_o, _, _ = state[:8]
            for new in caps:
                if new >= cur:
                    continue
                out = _build_batch_shrink_fn(new)(
                    src_o, dst_o, key_o, slot_o)
                jax.block_until_ready(out)
                compiled += 1
    return compiled


def _lattice_contract_bits(params: GHSParams) -> Optional[Tuple[int, int]]:
    """Uniform contraction bit-widths for a bounded serving lattice.

    When the params carry per-graph capacity bounds (the §12 service), every
    bucket's packed rounds can use the LATTICE TOP's (s_bits, c_bits)
    instead of its own: wider shift widths are sound (labels < n_pad ≤
    n_top, slot ids < cap ≤ cap_top, and the ≤ 64-bit gate is checked at
    the top), and uniform widths mean ONE jit fn object — hence one set of
    per-shape executables — serves every cap's compaction ladder.  Without
    this, each original cap keys its own fn object and the warmup lattice
    compiles O(shapes · ladder) distinct interval executables whose JIT
    code mappings can exhaust ``vm.max_map_count`` (observed: a
    256-vertex/1024-edge lattice × 4 flush widths ran the process out of
    mmaps mid-warmup)."""
    if (params.compaction != "pow2" or not params.batch_max_vertices
            or not params.batch_max_edges):
        return None
    n_top = _pow2ceil(int(params.batch_max_vertices))
    cap_top = _pow2ceil(max(int(params.batch_max_edges), 8))
    s_bits = max(n_top - 1, 1).bit_length()
    c_bits = max(cap_top - 1, 1).bit_length()
    if 2 * s_bits + 30 + c_bits > 64:
        return None
    return (s_bits, c_bits)


def _widen_contract_bits(
        contract_bits: Optional[Tuple[int, int]],
        params: GHSParams) -> Optional[Tuple[int, int]]:
    """Promote a bucket's own contraction bits to the lattice-top widths
    when the params define a lattice that covers them (see
    :func:`_lattice_contract_bits`)."""
    if contract_bits is None:
        return None
    lat = _lattice_contract_bits(params)
    if (lat is not None and lat[0] >= contract_bits[0]
            and lat[1] >= contract_bits[1]):
        return lat
    return contract_bits


def _contract_gate(batch) -> Optional[Tuple[int, int]]:
    """(s_bits, c_bits) when the bucket's contraction quadruple fits one
    uint64 — fragment labels need ``log2(n_pad)`` bits each, weight bits 30
    (requires every weight < 2.0, which the (0, 1) invariant gives; checked
    against the actual keys so arbitrary host graphs stay safe), and the
    canonical edge id ``log2(cap)``.  ``None`` falls back to plain
    compaction (bit-identical either way, just fewer sort savings)."""
    s_bits = max(batch.n_pad - 1, 1).bit_length()
    c_bits = max(batch.cap - 1, 1).bit_length()
    if 2 * s_bits + 30 + c_bits > 64:
        return None
    real = batch.key != INF_KEY
    if np.any(real & ((batch.key >> np.uint64(32)) >= np.uint64(1 << 30))):
        return None
    return (s_bits, c_bits)


def _solve_bucket(
    batch,                       # pipeline.GraphBatch
    params: GHSParams,
    max_rounds: Optional[int],
) -> tuple[list[ForestResult], BatchStats]:
    """Run one shape bucket through the vmapped device round loop."""
    n_pad, cap, B = batch.n_pad, batch.cap, batch.batch_size
    contract_bits = (_contract_gate(batch)
                     if params.compaction == "pow2" else None)
    contract_bits = _widen_contract_bits(contract_bits, params)
    # round_kernel="pallas" under vmap: the fused formulation IS the packed
    # round (n-scale recording + hooking); what changes is the election
    # lowering — scatter-free sort when the bucket passes the bit gate and
    # every weight sits below 1.0 (keeps the all-ones dead sentinel
    # unreachable).  Ungated buckets keep the plain XLA fallback rounds.
    election = "scatter"
    if (runtime.resolve_round_kernel(params.round_kernel) == "pallas"
            and contract_bits is not None):
        real = batch.key != keys_lib.INF_KEY
        wbits = batch.key >> np.uint64(32)
        if not np.any(real & (wbits >= np.uint64(0x3F800000))):
            election = "sort"

    with enable_x64():
        src_d = jnp.asarray(batch.src)
        dst_d = jnp.asarray(batch.dst)
        key_d = jnp.asarray(batch.key)
        slot_d = jnp.asarray(batch.slot)
        comp_dev = jnp.asarray(
            np.broadcast_to(np.arange(n_pad, dtype=np.uint32),
                            (B, n_pad)).copy())
        mask_dev = jnp.zeros((B, cap), bool)
        done_dev = jnp.zeros((B,), bool)
        rdone_dev = jnp.zeros((B,), jnp.int32)

        overlap = (runtime.resolve_interval_pipeline(
            params.interval_pipeline) == 1)
        interval = max(params.batch_check_frequency, 1)
        cap_rounds = max_rounds or (n_pad + 2)
        stats = BatchStats(buckets=1, bucket_shapes=((n_pad, cap, B),))
        history = []
        box = dict(cur_cap=cap, dispatched=0, inflight=[])

        fn = _build_batch_interval_fn(params.use_pallas, contract_bits,
                                      election)

        def dispatch(s):
            comp, mask, src_d, dst_d, key_d, slot_d, done, rdone = s
            this_rounds = max(min(interval, cap_rounds - box["dispatched"]),
                              0)
            state = fn(comp, mask, src_d, dst_d, key_d, slot_d, done, rdone,
                       this_rounds)
            box["dispatched"] += this_rounds
            box["inflight"].append(box["cur_cap"])   # popped by finish (FIFO)
            # The interval's scalar summary: the per-graph done vector is
            # already reduced on device, so the host reads ONE flag per
            # interval no matter how many graphs ride the bucket.
            return state[:8], state[8:]

        def finish(s, vals):
            all_done, r, census = vals
            stats.rounds += int(r)
            stats.edges_scanned += int(r) * box["inflight"].pop(0) * B
            history.append(int(census))
            if bool(all_done):
                return s, True
            if params.compaction == "pow2":
                new_cap = max(_pow2ceil(int(census)), 8)
                if new_cap < box["cur_cap"]:   # shrink: ≤ log2 recompiles
                    comp, mask, src_d, dst_d, key_d, slot_d, done, rdone = s
                    if contract_bits is not None:
                        # Contraction already packed survivors to the
                        # front — shrinking is a static slice, no readback.
                        cfn = _build_batch_shrink_fn(new_cap)
                        src_d, dst_d, key_d, slot_d = cfn(
                            src_d, dst_d, key_d, slot_d)
                    else:
                        cfn = _build_batch_compact_fn(new_cap)
                        src_d, dst_d, key_d, slot_d = cfn(
                            comp, src_d, dst_d, key_d, slot_d)
                    s = (comp, mask, src_d, dst_d, key_d, slot_d, done,
                         rdone)
                    box["cur_cap"] = new_cap
                    stats.compactions += 1
            return s, False

        state = runtime.interval_loop(
            (comp_dev, mask_dev, src_d, dst_d, key_d, slot_d, done_dev,
             rdone_dev), dispatch, finish, stats=stats,
            max_intervals=cap_rounds,
            fail_msg="batched Borůvka engine failed to converge",
            overlap=overlap)
        mask_dev, rdone_dev = state[1], state[7]

        # The bucket's single final fetch: mask + per-graph round counts.
        mask_h, rdone_h = jax.device_get((mask_dev, rdone_dev))
        stats.host_syncs += 1          # the bucket's final fetch
        stats.extra_syncs += 1

    results = batch.unpack(mask_h)
    stats.active_history = tuple(history)
    stats.rounds_per_graph = tuple(int(x) for x in np.asarray(rdone_h))
    return results, stats


def solve_packed(
    batch,                       # pipeline.GraphBatch
    params: GHSParams = DEFAULT_PARAMS,
    max_rounds: Optional[int] = None,
) -> tuple[list[ForestResult], BatchStats]:
    """Solve ONE pre-packed shape bucket (DESIGN.md §12).

    The incremental counterpart of :func:`minimum_spanning_forests`: a
    serving loop that routed requests through
    :func:`repro.core.pipeline.bucket_shape` and packed a queue with
    :func:`repro.core.pipeline.pack_bucket` dispatches the bucket here
    without re-listing (or re-bucketing) the batch.  Results come back in
    lane order; each forest is bit-identical to the single-graph solve.
    Device loop only — the host fallback has no packed form.
    """
    if runtime.resolve_round_loop(params.round_loop) != "device":
        raise ValueError(
            "solve_packed requires round_loop='device'; the host loop "
            "solves graphs one at a time via minimum_spanning_forest")
    for r, g in enumerate(batch.graphs):
        if np.any(g.weight.view(np.uint32) == INF32):
            raise ValueError(
                f"lane {r}: weights collide with the INF sentinel")
    return _solve_bucket(batch, params, max_rounds)


def minimum_spanning_forests(
    graphs,
    params: GHSParams = DEFAULT_PARAMS,
    max_rounds: Optional[int] = None,
) -> tuple[list[ForestResult], BatchStats]:
    """Solve many graphs per dispatch (DESIGN.md §8).

    Graphs are bucketed by padded shape (:func:`repro.core.pipeline.
    pack_batch` under the ``params.batch_bucket`` policy) and each bucket
    runs the device round loop under ``jax.vmap`` — one dispatch and one
    scalar readback per interval for the WHOLE bucket, amortizing compile
    and dispatch cost across the batch.  Results come back in input order
    and every forest is bit-identical to the corresponding single-graph
    :func:`minimum_spanning_forest` solve (same ops per lane, same packed
    total order).

    ``params.round_loop == "host"`` falls back to a loop of single-graph
    solves (the bench baseline); the batched fast path is device-only.
    """
    from repro.core import pipeline as pipeline_lib

    graph_list = [runtime.as_graph(g) for g in graphs]
    for i, g in enumerate(graph_list):
        if np.any(g.weight.view(np.uint32) == INF32):
            raise ValueError(
                f"graph {i}: weights collide with the INF sentinel")

    # Bucket + validate FIRST: the batch_bucket policy and the
    # batch_max_vertices/batch_max_edges capacity guards must reject bad
    # inputs on every loop driver, not just the vmapped fast path.
    batches = pipeline_lib.pack_batch(
        graph_list, bucket=params.batch_bucket,
        max_vertices=params.batch_max_vertices or None,
        max_edges=params.batch_max_edges or None)

    if runtime.resolve_round_loop(params.round_loop) == "host":
        stats = BatchStats()
        results = []
        rounds = []
        for g in graph_list:
            res, st = _host_engine(g, params, None, max_rounds)
            results.append(res)
            rounds.append(st.rounds)
            stats.merge(st)
        stats.rounds_per_graph = tuple(rounds)
        return results, stats

    results: list = [None] * len(graph_list)
    rounds = [0] * len(graph_list)
    stats = BatchStats()
    shapes = []
    for batch in batches:
        bres, bst = _solve_bucket(batch, params, max_rounds)
        for idx, res, r in zip(batch.indices, bres, bst.rounds_per_graph):
            results[idx] = res
            rounds[idx] = r
        stats.merge(bst)
        shapes.extend(bst.bucket_shapes)
    stats.buckets = len(batches)
    stats.bucket_shapes = tuple(shapes)
    stats.rounds_per_graph = tuple(rounds)
    return results, stats


# ---------------------------------------------------------------------------
# Legacy host-driven loop (round_loop="host"): per-round syncs + host-side
# compaction.  Kept as the before/after baseline for bench_round_loop.py.
# ---------------------------------------------------------------------------

def _segmin_scatter(n, idx, val, order=None):
    """Per-segment min via XLA scatter-min (default path)."""
    return jnp.full((n,), INF32, jnp.uint32).at[idx].min(val)


def _segmin_pallas(n, idx, val, order=None):
    """Per-segment min via the Pallas sort+scan kernel (TPU hot-spot path;
    interpret-mode on CPU, validated bit-equal to the scatter path)."""
    from repro.kernels.segment_min import ops as segops
    return segops.segment_min(val, idx.astype(jnp.int32), num_segments=n,
                              use_pallas=True, order=order)


def _round_body(
    comp: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    wbits: jnp.ndarray,
    eid: jnp.ndarray,
    *,
    axis_name: Optional[str],
    use_pallas: bool = False,
):
    """One two-phase round: elect MOE per fragment, hook, compress, relabel."""
    n = comp.shape[0]
    pmin = (lambda x: jax.lax.pmin(x, axis_name)) if axis_name else (lambda x: x)
    segmin = _segmin_pallas if use_pallas else _segmin_scatter

    cs = comp[src]
    cd = comp[dst]
    alive = (cs != cd) & (wbits != INF32)
    wb = jnp.where(alive, wbits, INF32)

    # Sort once per endpoint array, reuse across both election phases.
    order_s = jnp.argsort(cs.astype(jnp.int32)) if use_pallas else None
    order_d = jnp.argsort(cd.astype(jnp.int32)) if use_pallas else None

    # Phase 1: best weight per fragment (local scatter-min, global pmin).
    bw = jnp.minimum(segmin(n, cs, wb, order_s), segmin(n, cd, wb, order_d))
    bw = pmin(bw)

    # Phase 2: tie-break by unique edge id among weight-matching edges.
    cand_s = jnp.where(alive & (wb == bw[cs]), eid, INF32)
    cand_d = jnp.where(alive & (wb == bw[cd]), eid, INF32)
    be = jnp.minimum(segmin(n, cs, cand_s, order_s),
                     segmin(n, cd, cand_d, order_d))
    be = pmin(be)

    # Winners: the elected MOE edges (each fragment elects exactly one).
    winners = alive & ((be[cs] == eid) | (be[cd] == eid))

    # Merge: min-hooking + pointer doubling (GHS Connect/Initiate collapse).
    hi = jnp.maximum(cs, cd).astype(jnp.uint32)
    lo = jnp.minimum(cs, cd).astype(jnp.uint32)
    parent = union_find.hook_min(n, hi, lo, winners)
    parent = pmin(parent)
    parent = union_find.pointer_double(parent)
    new_comp = parent[comp]

    done = jnp.all(bw == INF32)
    return new_comp, winners, done


def _make_round_fn(mesh: Optional[Mesh], use_pallas: bool = False) -> Callable:
    if mesh is None:
        return jax.jit(partial(_round_body, axis_name=None,
                               use_pallas=use_pallas))
    fn = compat.shard_map(
        partial(_round_body, axis_name=_AXIS, use_pallas=use_pallas),
        mesh,
        in_specs=(P(), P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS)),
        out_specs=(P(), P(_AXIS), P()),
    )
    return jax.jit(fn)


def _host_engine(
    source,
    params: GHSParams,
    mesh: Optional[Mesh],
    max_rounds: Optional[int],
) -> tuple[ForestResult, BoruvkaStats]:
    graph = runtime.as_graph(source)
    n, m = graph.num_vertices, graph.num_edges
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    chunk = max(8 * num_shards, num_shards)

    src = graph.src.astype(np.int32)
    dst = graph.dst.astype(np.int32)
    wbits = graph.weight.view(np.uint32).copy()
    eid = np.arange(m, dtype=np.uint32)
    if np.any(wbits == INF32):
        raise ValueError("weights collide with the INF sentinel")

    # The legacy loop tracks edges by canonical id end to end, so a
    # partitioner is simply the initial upload order here (compaction
    # re-block-distributes the survivors, as the seed driver always did).
    part = partition_lib.get_partitioner(params.partitioner)
    if part.name != "block" and m:
        order = np.concatenate([
            np.flatnonzero(part.edge_shard(graph, num_shards) == s)
            for s in range(num_shards)
        ]).astype(np.int64)
    else:
        order = np.arange(m, dtype=np.int64)

    round_fn = _make_round_fn(mesh, use_pallas=params.use_pallas)
    comp_sharding = (
        NamedSharding(mesh, P()) if mesh is not None else None
    )
    edge_sharding = (
        NamedSharding(mesh, P(_AXIS)) if mesh is not None else None
    )

    stats = BoruvkaStats()

    def put_edges(arrs):
        arrs = _pad_pow2(arrs, chunk, [PAD_VERTEX, PAD_VERTEX, INF32, INF32])
        stats.host_syncs += 1          # host→device re-upload
        stats.extra_syncs += 1
        if edge_sharding is not None:
            return [jax.device_put(a, edge_sharding) for a in arrs]
        return [jnp.asarray(a) for a in arrs]

    comp = np.arange(n, dtype=np.uint32)
    comp_dev = (
        jax.device_put(comp, comp_sharding) if comp_sharding is not None
        else jnp.asarray(comp)
    )
    src_d, dst_d, wb_d, eid_d = put_edges(
        [src[order], dst[order], wbits[order], eid[order]])

    mask = np.zeros(m, dtype=bool)
    history = []
    cap = max_rounds or (n + 2)
    # Host mirror of the active edge set (for compaction + winner mapping).
    box = dict(active=order.copy())

    def dispatch(s):
        comp_dev, src_d, dst_d, wb_d, eid_d, _ = s
        comp_dev, winners, done = round_fn(comp_dev, src_d, dst_d, wb_d,
                                           eid_d)
        # The runtime fetches the done flag (the legacy loop's per-round
        # sync); the winner bitmap readback below is an extra, metered one.
        return (comp_dev, src_d, dst_d, wb_d, eid_d, winners), done

    def finish(s, done_v):
        comp_dev, src_d, dst_d, wb_d, eid_d, winners = s
        rnd = stats.rounds
        stats.rounds += 1
        stats.edges_scanned += int(src_d.shape[0])
        history.append(len(box["active"]))
        if bool(done_v):
            return s, True
        stats.host_syncs += 1          # device→host: winner bitmap + ids
        stats.extra_syncs += 1
        w = np.asarray(winners)
        if w.any():
            eids = np.asarray(eid_d)[w]
            mask[eids[eids != INF32].astype(np.int64)] = True
        # C1 analogue: lazy compaction every check_frequency rounds.
        if (
            params.compaction == "pow2"
            and (rnd + 1) % max(params.check_frequency, 1) == 0
        ):
            stats.host_syncs += 1      # device→host: fragment labels
            stats.extra_syncs += 1
            comp_h = np.asarray(comp_dev)
            active = box["active"]
            keep = comp_h[src[active]] != comp_h[dst[active]]
            if not keep.all():
                box["active"] = active = active[keep]
                stats.compactions += 1
                src_d, dst_d, wb_d, eid_d = put_edges(
                    [src[active], dst[active],
                     wbits[active], eid[active].astype(np.uint32)]
                )
                s = (comp_dev, src_d, dst_d, wb_d, eid_d, winners)
        return s, False

    comp_dev = runtime.interval_loop(
        (comp_dev, src_d, dst_d, wb_d, eid_d, None), dispatch, finish,
        stats=stats, max_intervals=cap,
        fail_msg="Borůvka engine failed to converge")[0]

    comp_final = np.asarray(comp_dev)
    ncomp = int(np.unique(comp_final).size)
    res = runtime.forest_from_mask(graph, mask, num_components=ncomp)
    res.check_consistent(n)
    stats.active_history = tuple(history)
    return res, stats


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def minimum_spanning_forest(
    graph,
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    max_rounds: Optional[int] = None,
) -> tuple[ForestResult, BoruvkaStats]:
    """Run the optimized engine; returns the forest + execution stats.

    ``graph`` is a host :class:`Graph` or a device-resident
    :class:`repro.core.pipeline.DeviceEdges` — with the latter (and the
    default ``block`` partitioner) edges flow from the generation pipeline
    into the round loop without ever visiting host memory.

    ``params.round_loop`` selects the loop driver: ``"device"`` (default) is
    the fused host-sync-free ``lax.while_loop`` engine; ``"host"`` is the
    legacy per-round host loop.  ``params.partitioner`` picks the edge
    distribution (block / hashed / balanced — DESIGN.md §7).  All
    combinations produce bit-identical forests.

    This entry is also the sub-solver of the filter-Borůvka hybrid
    (:mod:`repro.core.filter_boruvka`, DESIGN.md §10): the sample and
    final solves are ordinary invocations over canonical-order subset
    graphs, so every knob above composes with ``method="filter_boruvka"``
    unchanged.
    """
    if runtime.resolve_round_loop(params.round_loop) == "host":
        return _host_engine(graph, params, mesh, max_rounds)
    return _device_engine(graph, params, mesh, max_rounds)
