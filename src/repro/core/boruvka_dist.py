"""Optimized synchronous distributed Borůvka/GHS engine (beyond-paper, §3 of DESIGN).

Re-formulates GHS for SPMD hardware: per round, every fragment's minimum
outgoing edge (MOE) is a segment-min over (weight-bits, edge-id) — GHS's
``Test``/``Report`` message waves collapse into two scatter-min passes and one
fused ``pmin`` collective; fragment merging is min-hooking + pointer doubling
(the ``Connect``/``Initiate`` waves).  The paper's point-to-point short-message
traffic — which it identifies as its limiting factor (§4.2) — is off the
critical path entirely.

Edges are block-distributed across devices (`shard_map` over axis ``"x"``);
the fragment-label array ``comp`` is replicated (paper layout: vertices are
block-distributed, but labels are small — int32 per vertex).

Tie-breaking uses the two-word (weight_bits:u32, edge_id:u32) total order, the
same order as :mod:`repro.core.keys` — see DESIGN.md §2/C3 for why this stays
in 32-bit lanes instead of the paper's 64-bit ``special_id``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import union_find
from repro.core.graph import Graph
from repro.core.kruskal_ref import ForestResult
from repro.core.params import DEFAULT_PARAMS, GHSParams

INF32 = np.uint32(0xFFFFFFFF)
_AXIS = "x"


# ---------------------------------------------------------------------------
# One Borůvka round (runs per shard; axis_name=None → single device)
# ---------------------------------------------------------------------------

def _segmin_scatter(n: int, idx: jnp.ndarray, val: jnp.ndarray) -> jnp.ndarray:
    """Per-segment min via XLA scatter-min (default path)."""
    return jnp.full((n,), INF32, jnp.uint32).at[idx].min(val)


def _segmin_pallas(n: int, idx: jnp.ndarray, val: jnp.ndarray) -> jnp.ndarray:
    """Per-segment min via the Pallas sort+scan kernel (TPU hot-spot path;
    interpret-mode on CPU, validated bit-equal to the scatter path)."""
    from repro.kernels.segment_min import ops as segops
    return segops.segment_min(val, idx.astype(jnp.int32), num_segments=n,
                              use_pallas=True)


def _round_body(
    comp: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    wbits: jnp.ndarray,
    eid: jnp.ndarray,
    *,
    axis_name: Optional[str],
    use_pallas: bool = False,
):
    """One round: elect MOE per fragment, hook, compress, relabel."""
    n = comp.shape[0]
    pmin = (lambda x: jax.lax.pmin(x, axis_name)) if axis_name else (lambda x: x)
    segmin = _segmin_pallas if use_pallas else _segmin_scatter

    cs = comp[src]
    cd = comp[dst]
    alive = (cs != cd) & (wbits != INF32)
    wb = jnp.where(alive, wbits, INF32)

    # Phase 1: best weight per fragment (local scatter-min, global pmin).
    bw = jnp.minimum(segmin(n, cs, wb), segmin(n, cd, wb))
    bw = pmin(bw)

    # Phase 2: tie-break by unique edge id among weight-matching edges.
    cand_s = jnp.where(alive & (wb == bw[cs]), eid, INF32)
    cand_d = jnp.where(alive & (wb == bw[cd]), eid, INF32)
    be = jnp.minimum(segmin(n, cs, cand_s), segmin(n, cd, cand_d))
    be = pmin(be)

    # Winners: the elected MOE edges (each fragment elects exactly one).
    winners = alive & ((be[cs] == eid) | (be[cd] == eid))

    # Merge: min-hooking + pointer doubling (GHS Connect/Initiate collapse).
    hi = jnp.maximum(cs, cd).astype(jnp.uint32)
    lo = jnp.minimum(cs, cd).astype(jnp.uint32)
    parent = union_find.hook_min(n, hi, lo, winners)
    parent = pmin(parent)
    parent = union_find.pointer_double(parent)
    new_comp = parent[comp]

    done = jnp.all(bw == INF32)
    return new_comp, winners, done


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BoruvkaStats:
    rounds: int = 0
    compactions: int = 0
    edges_scanned: int = 0          # Σ active (padded) edges per round
    active_history: tuple = ()      # active edge count per round (Fig 4 analogue)


def _make_round_fn(mesh: Optional[Mesh], use_pallas: bool = False) -> Callable:
    if mesh is None:
        return jax.jit(partial(_round_body, axis_name=None,
                               use_pallas=use_pallas))
    fn = jax.shard_map(
        partial(_round_body, axis_name=_AXIS, use_pallas=use_pallas),
        mesh=mesh,
        in_specs=(P(), P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS)),
        out_specs=(P(), P(_AXIS), P()),
        check_vma=False,
    )
    return jax.jit(fn)


def _pad_pow2(arrs, multiple: int, fill_vals):
    m = arrs[0].shape[0]
    target = multiple
    while target < m:
        target *= 2
    pad = target - m
    return [
        np.concatenate([a, np.full(pad, f, a.dtype)]) if pad else a
        for a, f in zip(arrs, fill_vals)
    ]


def minimum_spanning_forest(
    graph: Graph,
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    max_rounds: Optional[int] = None,
) -> tuple[ForestResult, BoruvkaStats]:
    """Run the optimized engine; returns the forest + execution stats."""
    n, m = graph.num_vertices, graph.num_edges
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    chunk = max(8 * num_shards, num_shards)

    src = graph.src.astype(np.int32)
    dst = graph.dst.astype(np.int32)
    wbits = graph.weight.view(np.uint32).copy()
    eid = np.arange(m, dtype=np.uint32)
    if np.any(wbits == INF32):
        raise ValueError("weights collide with the INF sentinel")

    round_fn = _make_round_fn(mesh, use_pallas=params.use_pallas)
    comp_sharding = (
        NamedSharding(mesh, P()) if mesh is not None else None
    )
    edge_sharding = (
        NamedSharding(mesh, P(_AXIS)) if mesh is not None else None
    )

    def put_edges(arrs):
        arrs = _pad_pow2(arrs, chunk, [0, 0, INF32, INF32])
        if edge_sharding is not None:
            return [jax.device_put(a, edge_sharding) for a in arrs]
        return [jnp.asarray(a) for a in arrs]

    comp = np.arange(n, dtype=np.uint32)
    comp_dev = (
        jax.device_put(comp, comp_sharding) if comp_sharding is not None
        else jnp.asarray(comp)
    )
    src_d, dst_d, wb_d, eid_d = put_edges([src, dst, wbits, eid])
    # Host mirror of the active edge set (for compaction + winner mapping).
    active = np.arange(m, dtype=np.int64)

    mask = np.zeros(m, dtype=bool)
    stats = BoruvkaStats()
    history = []
    cap = max_rounds or (n + 2)

    for rnd in range(cap):
        comp_dev, winners, done = round_fn(comp_dev, src_d, dst_d, wb_d, eid_d)
        stats.rounds += 1
        stats.edges_scanned += int(src_d.shape[0])
        history.append(len(active))
        if bool(done):
            break
        w = np.asarray(winners)
        if w.any():
            eids = np.asarray(eid_d)[w]
            mask[eids[eids != INF32].astype(np.int64)] = True
        # C1 analogue: lazy compaction every check_frequency rounds.
        if (
            params.compaction == "pow2"
            and (rnd + 1) % max(params.check_frequency, 1) == 0
        ):
            comp_h = np.asarray(comp_dev)
            keep = comp_h[src[active]] != comp_h[dst[active]]
            if not keep.all():
                active = active[keep]
                stats.compactions += 1
                src_d, dst_d, wb_d, eid_d = put_edges(
                    [src[active], dst[active],
                     wbits[active], eid[active].astype(np.uint32)]
                )
    else:
        raise RuntimeError("Borůvka engine failed to converge")

    comp_final = np.asarray(comp_dev)
    ncomp = int(np.unique(comp_final).size)
    total = float(graph.weight[mask].sum(dtype=np.float64))
    res = ForestResult(
        total_weight=total,
        edge_mask=mask,
        num_components=ncomp,
        num_tree_edges=int(mask.sum()),
    )
    res.check_consistent(n)
    stats.active_history = tuple(history)
    return res, stats
