"""Device-resident synchronous Borůvka/GHS engine (beyond-paper, DESIGN §3-4).

Re-formulates GHS for SPMD hardware: per round, every fragment's minimum
outgoing edge (MOE) is ONE segmented min over packed 64-bit keys
``(weight_bits << 32) | edge_id`` — GHS's ``Test``/``Report`` message waves
collapse into a single scatter-min sweep and a single fused ``pmin``
collective (the two-phase weight + tie-break election of earlier versions is
gone; the packed key resolves both in the same reduction).  Fragment merging
is min-hooking + pointer doubling (the ``Connect``/``Initiate`` waves).  The
paper's point-to-point short-message traffic — which it identifies as its
limiting factor (§4.2) — is off the critical path entirely.

The round loop itself is device-resident: a ``jax.lax.while_loop`` advances
up to ``check_frequency`` rounds per dispatch, accumulating tree edges into
an on-device ``edge_mask`` and testing termination on device, so the host
synchronizes ONCE per compaction interval instead of once (or more) per
round.  Edge compaction is an on-device prefix-sum stream compaction into
power-of-two buckets; edges never round-trip through host memory.

Edges are block-distributed across devices (shard_map over axis ``"x"``);
the fragment-label array ``comp`` is replicated (paper layout: vertices are
block-distributed, but labels are small — int32 per vertex).  The legacy
host-driven loop is retained as ``params.round_loop == "host"`` for the
before/after measurement in ``benchmarks/bench_round_loop.py`` and as an
ablation baseline; both loops are bit-identical to the Kruskal oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import keys as keys_lib
from repro.core import partition as partition_lib
from repro.core import runtime
from repro.core import union_find
from repro.core.graph import PAD_VERTEX, Graph
from repro.core.kruskal_ref import ForestResult
from repro.core.params import DEFAULT_PARAMS, GHSParams

INF32 = np.uint32(0xFFFFFFFF)
INF_KEY = keys_lib.INF_KEY
_AXIS = "x"


def _pad_pow2(arrs, multiple: int, fill_vals):
    """Pad to the next power-of-two multiple of ``multiple``.

    src/dst are filled with PAD_VERTEX (far out of vertex range — clamped
    gathers make padding edges self-loops by construction, see graph.py),
    keys/weights with their INF sentinel.
    """
    m = arrs[0].shape[0]
    target = multiple
    while target < m:
        target *= 2
    pad = target - m
    return [
        np.concatenate([a, np.full(pad, f, a.dtype)]) if pad else a
        for a, f in zip(arrs, fill_vals)
    ]


# Power-of-two bucket sizing shared with the layout builder.
_pow2ceil = partition_lib.pow2ceil


@dataclasses.dataclass
class BoruvkaStats(runtime.EngineStats):
    # host_syncs / intervals inherited from the runtime protocol; for the
    # legacy host loop, intervals == rounds (one dispatch per round).
    rounds: int = 0
    compactions: int = 0
    edges_scanned: int = 0          # Σ active (padded) edges per round
    active_history: tuple = ()      # host loop: global active edges per round;
                                    # device loop: MAX per-shard active count
                                    # per interval (the compaction-cap census)


# ---------------------------------------------------------------------------
# Fused device-resident loop (round_loop="device", the default)
# ---------------------------------------------------------------------------

def _run_interval(
    comp: jnp.ndarray,
    mask: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    key: jnp.ndarray,
    slot: jnp.ndarray,
    rounds: jnp.ndarray,
    *,
    axis_name: Optional[str],
    use_pallas: bool,
):
    """Advance up to ``rounds`` Borůvka rounds entirely on device.

    State per shard: replicated fragment labels ``comp``, the per-slot tree
    bitmap ``mask`` (frozen in the load-time layout of the partitioner —
    slot i on shard s is canonical edge ``layout.eid[s*block + i]``), and
    the (possibly compacted) local edge arrays.  Each edge carries its own
    load-time ``slot`` index, so winner recording is a local scatter under
    ANY partition and survives compaction.  Returns the new state plus a
    replicated (done, rounds-run, max local active count) triple — the ONLY
    values the host ever reads.
    """
    n = comp.shape[0]
    cap = mask.shape[0]
    pmin = (lambda x: jax.lax.pmin(x, axis_name)) if axis_name else (lambda x: x)

    def one_round(comp, mask):
        cs = comp[src]          # PAD_VERTEX clamps → padding is a self-loop
        cd = comp[dst]
        alive = (cs != cd) & (key != INF_KEY)
        k = jnp.where(alive, key, INF_KEY)
        # Fused MOE election: ONE segmented min over both endpoints, ONE
        # collective.  The packed key carries the tie-break, so no second
        # (weight-match, edge-id) pass and no second pmin.
        seg = jnp.concatenate([cs, cd]).astype(jnp.int32)
        from repro.kernels.segment_min import ops as segops
        best = segops.segment_min64(
            jnp.concatenate([k, k]), seg, num_segments=n,
            use_pallas=use_pallas)
        best = pmin(best)
        winners = alive & ((best[cs] == k) | (best[cd] == k))
        # Record wins into the sharded bitmap; an edge's bitmap slot lives on
        # the shard that loaded it (compaction is shard-local), so the
        # scatter is local for every partitioner.
        mask = mask.at[jnp.where(winners, slot, cap)].set(True, mode="drop")
        # Merge: min-hooking + pointer doubling (GHS Connect/Initiate).
        hi = jnp.maximum(cs, cd).astype(jnp.uint32)
        lo = jnp.minimum(cs, cd).astype(jnp.uint32)
        parent = union_find.hook_min(n, hi, lo, winners)
        parent = pmin(parent)
        parent = union_find.pointer_double(parent)
        done = jnp.all(best == INF_KEY)
        return parent[comp], mask, done

    def cond(c):
        r, _, _, done = c
        return jnp.logical_not(done) & (r < rounds)

    def body(c):
        r, comp, mask, _ = c
        comp, mask, done = one_round(comp, mask)
        return r + 1, comp, mask, done

    r, comp, mask, done = jax.lax.while_loop(
        cond, body, (jnp.int32(0), comp, mask, jnp.bool_(False)))

    # Active-edge census for the host's compaction-bucket choice.
    active = (comp[src] != comp[dst]) & (key != INF_KEY)
    n_active = active.sum(dtype=jnp.int32)
    if axis_name:
        n_active = jax.lax.pmax(n_active, axis_name)
    return comp, mask, done, r, n_active


_PAD_SLOT = np.int32(0x7FFF0000)   # out of any mask range → scatter-dropped


def _compact_shard(comp, src, dst, key, slot, *, cap: int):
    """Prefix-sum stream compaction of the local edge block to ``cap`` slots.

    Runs entirely on device — dead edges (endpoints in the same fragment)
    are dropped, survivors slide to the front (carrying their load-time
    bitmap ``slot``), the tail refills with the inert padding sentinels.
    ``cap`` is static (a power-of-two bucket), so shapes stay rectangular
    across shards.
    """
    keep = (comp[src] != comp[dst]) & (key != INF_KEY)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    idx = jnp.where(keep, pos, cap)
    new_src = jnp.full((cap,), PAD_VERTEX, jnp.int32).at[idx].set(
        src, mode="drop")
    new_dst = jnp.full((cap,), PAD_VERTEX, jnp.int32).at[idx].set(
        dst, mode="drop")
    new_key = jnp.full((cap,), INF_KEY, jnp.uint64).at[idx].set(
        key, mode="drop")
    new_slot = jnp.full((cap,), _PAD_SLOT, jnp.int32).at[idx].set(
        slot, mode="drop")
    return new_src, new_dst, new_key, new_slot


@functools.lru_cache(maxsize=64)
def _build_interval_fn(mesh: Optional[Mesh], use_pallas: bool) -> Callable:
    # rounds is a traced scalar, so one executable serves every interval
    # length and graph size per (mesh, shapes).  comp/mask are the mutated
    # state — donate so device buffers are reused in place.
    donate = runtime.donation(0, 1)
    if mesh is None:
        fn = partial(_run_interval, axis_name=None, use_pallas=use_pallas)
        return jax.jit(fn, donate_argnums=donate)
    fn = compat.shard_map(
        partial(_run_interval, axis_name=_AXIS, use_pallas=use_pallas),
        mesh,
        in_specs=(P(), P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS),
                  P()),
        out_specs=(P(), P(_AXIS), P(), P(), P()),
    )
    return jax.jit(fn, donate_argnums=donate)


@functools.lru_cache(maxsize=64)
def _build_compact_fn(mesh: Optional[Mesh], cap: int) -> Callable:
    # No donation here: compaction is shrink-only, so the inputs are strictly
    # larger than the outputs and could never alias them anyway.
    if mesh is None:
        return jax.jit(partial(_compact_shard, cap=cap))
    fn = compat.shard_map(
        partial(_compact_shard, cap=cap), mesh,
        in_specs=(P(), P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS)),
        out_specs=(P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS)),
    )
    return jax.jit(fn)


def _device_engine(
    source,
    params: GHSParams,
    mesh: Optional[Mesh],
    max_rounds: Optional[int],
) -> tuple[ForestResult, BoruvkaStats]:
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    chunk = max(8 * num_shards, num_shards)

    if isinstance(source, Graph):
        # Host-built weights may be arbitrary; the pipeline's are (0, 1) by
        # construction, so only the host path needs the sentinel check.
        if np.any(source.weight.view(np.uint32) == INF32):
            raise ValueError("weights collide with the INF sentinel")

    with enable_x64():
        bundle = runtime.prepare_edges(
            source, params.partitioner, mesh, chunk=chunk)
        n, m = bundle.num_vertices, bundle.num_edges
        layout = bundle.layout
        m0 = layout.num_slots

        edge_sh = NamedSharding(mesh, P(_AXIS)) if mesh is not None else None
        repl_sh = NamedSharding(mesh, P()) if mesh is not None else None

        def put(a, sh):
            return jax.device_put(a, sh) if sh is not None else jnp.asarray(a)

        src_d, dst_d, key_d, slot_d = (bundle.src, bundle.dst, bundle.key,
                                       bundle.slot)
        comp_dev = put(np.arange(n, dtype=np.uint32), repl_sh)
        mask_dev = put(np.zeros(m0, dtype=bool), edge_sh)

        interval = max(params.check_frequency, 1)
        cap_rounds = max_rounds or (n + 2)
        stats = BoruvkaStats()
        history = []
        box = dict(cur_block=layout.block)

        fn = _build_interval_fn(mesh, params.use_pallas)

        def dispatch(s):
            comp_dev, mask_dev, src_d, dst_d, key_d, slot_d = s
            this_rounds = min(interval, cap_rounds - stats.rounds)
            comp_dev, mask_dev, done_t, r_t, act_t = fn(
                comp_dev, mask_dev, src_d, dst_d, key_d, slot_d, this_rounds)
            # The interval's scalar summary: three replicated values,
            # fetched by the runtime with ONE device_get.
            return (comp_dev, mask_dev, src_d, dst_d, key_d, slot_d), \
                (done_t, r_t, act_t)

        def finish(s, vals):
            done_v, r, n_act = vals
            stats.rounds += int(r)
            stats.edges_scanned += int(r) * box["cur_block"] * num_shards
            history.append(int(n_act))
            if bool(done_v):
                return s, True
            if params.compaction == "pow2":
                new_block = max(_pow2ceil(int(n_act)), 8)
                if new_block < box["cur_block"]:   # shrink: ≤ log2 recompiles
                    cfn = _build_compact_fn(mesh, new_block)
                    comp_dev, mask_dev, src_d, dst_d, key_d, slot_d = s
                    src_d, dst_d, key_d, slot_d = cfn(
                        comp_dev, src_d, dst_d, key_d, slot_d)
                    s = (comp_dev, mask_dev, src_d, dst_d, key_d, slot_d)
                    box["cur_block"] = new_block
                    stats.compactions += 1
            return s, False

        comp_dev, mask_dev = runtime.interval_loop(
            (comp_dev, mask_dev, src_d, dst_d, key_d, slot_d), dispatch,
            finish, stats=stats, max_intervals=cap_rounds,
            fail_msg="Borůvka engine failed to converge")[:2]

        comp_final, mask_full = jax.device_get((comp_dev, mask_dev))
        stats.host_syncs += 1

    comp_final = np.asarray(comp_final)
    # The bitmap lives in the load-time slot layout; the layout maps slots
    # back to canonical edge ids (padding slots never set).
    mask = layout.canonical_mask(np.asarray(mask_full), m)
    ncomp = int(np.unique(comp_final).size)
    res = runtime.forest_from_mask(bundle.graph(), mask, num_components=ncomp)
    res.check_consistent(n)
    stats.active_history = tuple(history)
    return res, stats


# ---------------------------------------------------------------------------
# Legacy host-driven loop (round_loop="host"): per-round syncs + host-side
# compaction.  Kept as the before/after baseline for bench_round_loop.py.
# ---------------------------------------------------------------------------

def _segmin_scatter(n, idx, val, order=None):
    """Per-segment min via XLA scatter-min (default path)."""
    return jnp.full((n,), INF32, jnp.uint32).at[idx].min(val)


def _segmin_pallas(n, idx, val, order=None):
    """Per-segment min via the Pallas sort+scan kernel (TPU hot-spot path;
    interpret-mode on CPU, validated bit-equal to the scatter path)."""
    from repro.kernels.segment_min import ops as segops
    return segops.segment_min(val, idx.astype(jnp.int32), num_segments=n,
                              use_pallas=True, order=order)


def _round_body(
    comp: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    wbits: jnp.ndarray,
    eid: jnp.ndarray,
    *,
    axis_name: Optional[str],
    use_pallas: bool = False,
):
    """One two-phase round: elect MOE per fragment, hook, compress, relabel."""
    n = comp.shape[0]
    pmin = (lambda x: jax.lax.pmin(x, axis_name)) if axis_name else (lambda x: x)
    segmin = _segmin_pallas if use_pallas else _segmin_scatter

    cs = comp[src]
    cd = comp[dst]
    alive = (cs != cd) & (wbits != INF32)
    wb = jnp.where(alive, wbits, INF32)

    # Sort once per endpoint array, reuse across both election phases.
    order_s = jnp.argsort(cs.astype(jnp.int32)) if use_pallas else None
    order_d = jnp.argsort(cd.astype(jnp.int32)) if use_pallas else None

    # Phase 1: best weight per fragment (local scatter-min, global pmin).
    bw = jnp.minimum(segmin(n, cs, wb, order_s), segmin(n, cd, wb, order_d))
    bw = pmin(bw)

    # Phase 2: tie-break by unique edge id among weight-matching edges.
    cand_s = jnp.where(alive & (wb == bw[cs]), eid, INF32)
    cand_d = jnp.where(alive & (wb == bw[cd]), eid, INF32)
    be = jnp.minimum(segmin(n, cs, cand_s, order_s),
                     segmin(n, cd, cand_d, order_d))
    be = pmin(be)

    # Winners: the elected MOE edges (each fragment elects exactly one).
    winners = alive & ((be[cs] == eid) | (be[cd] == eid))

    # Merge: min-hooking + pointer doubling (GHS Connect/Initiate collapse).
    hi = jnp.maximum(cs, cd).astype(jnp.uint32)
    lo = jnp.minimum(cs, cd).astype(jnp.uint32)
    parent = union_find.hook_min(n, hi, lo, winners)
    parent = pmin(parent)
    parent = union_find.pointer_double(parent)
    new_comp = parent[comp]

    done = jnp.all(bw == INF32)
    return new_comp, winners, done


def _make_round_fn(mesh: Optional[Mesh], use_pallas: bool = False) -> Callable:
    if mesh is None:
        return jax.jit(partial(_round_body, axis_name=None,
                               use_pallas=use_pallas))
    fn = compat.shard_map(
        partial(_round_body, axis_name=_AXIS, use_pallas=use_pallas),
        mesh,
        in_specs=(P(), P(_AXIS), P(_AXIS), P(_AXIS), P(_AXIS)),
        out_specs=(P(), P(_AXIS), P()),
    )
    return jax.jit(fn)


def _host_engine(
    source,
    params: GHSParams,
    mesh: Optional[Mesh],
    max_rounds: Optional[int],
) -> tuple[ForestResult, BoruvkaStats]:
    graph = runtime.as_graph(source)
    n, m = graph.num_vertices, graph.num_edges
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    chunk = max(8 * num_shards, num_shards)

    src = graph.src.astype(np.int32)
    dst = graph.dst.astype(np.int32)
    wbits = graph.weight.view(np.uint32).copy()
    eid = np.arange(m, dtype=np.uint32)
    if np.any(wbits == INF32):
        raise ValueError("weights collide with the INF sentinel")

    # The legacy loop tracks edges by canonical id end to end, so a
    # partitioner is simply the initial upload order here (compaction
    # re-block-distributes the survivors, as the seed driver always did).
    part = partition_lib.get_partitioner(params.partitioner)
    if part.name != "block" and m:
        order = np.concatenate([
            np.flatnonzero(part.edge_shard(graph, num_shards) == s)
            for s in range(num_shards)
        ]).astype(np.int64)
    else:
        order = np.arange(m, dtype=np.int64)

    round_fn = _make_round_fn(mesh, use_pallas=params.use_pallas)
    comp_sharding = (
        NamedSharding(mesh, P()) if mesh is not None else None
    )
    edge_sharding = (
        NamedSharding(mesh, P(_AXIS)) if mesh is not None else None
    )

    stats = BoruvkaStats()

    def put_edges(arrs):
        arrs = _pad_pow2(arrs, chunk, [PAD_VERTEX, PAD_VERTEX, INF32, INF32])
        stats.host_syncs += 1          # host→device re-upload
        if edge_sharding is not None:
            return [jax.device_put(a, edge_sharding) for a in arrs]
        return [jnp.asarray(a) for a in arrs]

    comp = np.arange(n, dtype=np.uint32)
    comp_dev = (
        jax.device_put(comp, comp_sharding) if comp_sharding is not None
        else jnp.asarray(comp)
    )
    src_d, dst_d, wb_d, eid_d = put_edges(
        [src[order], dst[order], wbits[order], eid[order]])

    mask = np.zeros(m, dtype=bool)
    history = []
    cap = max_rounds or (n + 2)
    # Host mirror of the active edge set (for compaction + winner mapping).
    box = dict(active=order.copy())

    def dispatch(s):
        comp_dev, src_d, dst_d, wb_d, eid_d, _ = s
        comp_dev, winners, done = round_fn(comp_dev, src_d, dst_d, wb_d,
                                           eid_d)
        # The runtime fetches the done flag (the legacy loop's per-round
        # sync); the winner bitmap readback below is an extra, metered one.
        return (comp_dev, src_d, dst_d, wb_d, eid_d, winners), done

    def finish(s, done_v):
        comp_dev, src_d, dst_d, wb_d, eid_d, winners = s
        rnd = stats.rounds
        stats.rounds += 1
        stats.edges_scanned += int(src_d.shape[0])
        history.append(len(box["active"]))
        if bool(done_v):
            return s, True
        stats.host_syncs += 1          # device→host: winner bitmap + ids
        w = np.asarray(winners)
        if w.any():
            eids = np.asarray(eid_d)[w]
            mask[eids[eids != INF32].astype(np.int64)] = True
        # C1 analogue: lazy compaction every check_frequency rounds.
        if (
            params.compaction == "pow2"
            and (rnd + 1) % max(params.check_frequency, 1) == 0
        ):
            stats.host_syncs += 1      # device→host: fragment labels
            comp_h = np.asarray(comp_dev)
            active = box["active"]
            keep = comp_h[src[active]] != comp_h[dst[active]]
            if not keep.all():
                box["active"] = active = active[keep]
                stats.compactions += 1
                src_d, dst_d, wb_d, eid_d = put_edges(
                    [src[active], dst[active],
                     wbits[active], eid[active].astype(np.uint32)]
                )
                s = (comp_dev, src_d, dst_d, wb_d, eid_d, winners)
        return s, False

    comp_dev = runtime.interval_loop(
        (comp_dev, src_d, dst_d, wb_d, eid_d, None), dispatch, finish,
        stats=stats, max_intervals=cap,
        fail_msg="Borůvka engine failed to converge")[0]

    comp_final = np.asarray(comp_dev)
    ncomp = int(np.unique(comp_final).size)
    res = runtime.forest_from_mask(graph, mask, num_components=ncomp)
    res.check_consistent(n)
    stats.active_history = tuple(history)
    return res, stats


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def minimum_spanning_forest(
    graph,
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    max_rounds: Optional[int] = None,
) -> tuple[ForestResult, BoruvkaStats]:
    """Run the optimized engine; returns the forest + execution stats.

    ``graph`` is a host :class:`Graph` or a device-resident
    :class:`repro.core.pipeline.DeviceEdges` — with the latter (and the
    default ``block`` partitioner) edges flow from the generation pipeline
    into the round loop without ever visiting host memory.

    ``params.round_loop`` selects the loop driver: ``"device"`` (default) is
    the fused host-sync-free ``lax.while_loop`` engine; ``"host"`` is the
    legacy per-round host loop.  ``params.partitioner`` picks the edge
    distribution (block / hashed / balanced — DESIGN.md §7).  All
    combinations produce bit-identical forests.
    """
    if runtime.resolve_round_loop(params.round_loop) == "host":
        return _host_engine(graph, params, mesh, max_rounds)
    return _device_engine(graph, params, mesh, max_rounds)
