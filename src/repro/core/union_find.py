"""Hooking + pointer-jumping primitives (JAX, fixed-shape, collective-safe).

This is the synchronous stand-in for GHS fragment merging: min-hooking builds
a strictly-decreasing parent forest (no cycles by construction), and pointer
doubling compresses it in ⌈log2 N⌉ steps — the O(log) collapse of the GHS
``Initiate`` broadcast described in DESIGN.md §3.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

INF32 = jnp.uint32(0xFFFFFFFF)


def hook_min(
    n: int, hi: jnp.ndarray, lo: jnp.ndarray, valid: jnp.ndarray
) -> jnp.ndarray:
    """Scatter-min hooking: parent[hi] = min(lo) over valid merge requests.

    ``hi > lo`` must hold for valid entries; invalid entries are inert.
    Returns the local parent contribution (combine across shards with pmin).
    """
    parent = jnp.arange(n, dtype=jnp.uint32)
    hi_idx = jnp.where(valid, hi, n)  # out-of-range drops the update
    return parent.at[hi_idx].min(jnp.where(valid, lo.astype(jnp.uint32), INF32),
                                 mode="drop")


def pointer_double(parent: jnp.ndarray, num_steps: int | None = None) -> jnp.ndarray:
    """Full path compression by pointer doubling (⌈log2 N⌉ gathers)."""
    n = parent.shape[0]
    if num_steps is None:
        num_steps = max(1, math.ceil(math.log2(max(n, 2))))

    def body(_, p):
        return p[p]

    return jax.lax.fori_loop(0, num_steps, body, parent)
