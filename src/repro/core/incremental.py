"""Incremental MST for evolving graphs — batched insert/delete (DESIGN.md §13).

Every engine solves from scratch; real serving traffic mutates graphs.
This module applies one :class:`EdgeBatch` of insertions and deletions to a
solved :class:`IncrementalForest` and returns the forest of the updated
graph, bit-identical to a from-scratch re-solve, at a fraction of the work.

The pass is the classical cycle/cut pair, made device-resident on the
existing fragment/label machinery (after *Time, Message and Memory-Optimal
Distributed MST and Partwise Aggregation*, Elkin & Goldenfeld, PAPERS.md —
partwise aggregation IS the cut/cycle verification primitive — and keeping
the probe batched rather than per-edge host logic after Sanders & Schimek,
PAPERS.md):

1. **Merge (host glue).**  :func:`apply_edge_batch` builds the updated
   graph deterministically: deletions remove canonical pairs, insertions
   run through the §3.1 preprocess semantics (self-loops dropped, per-pair
   minimum weight wins, ties keep the surviving copy).  This construction
   is the DEFINITION of the updated graph — the bit-identity reference is
   a full re-solve of exactly this graph.
2. **Anchor forest.**  ``F0`` = the old tree edges that survive unmodified
   (same pair, same weight).  A subset of a forest is a forest, and every
   F0 edge exists in the updated graph, so certificates built over F0 are
   certificates in the updated graph.
3. **Cycle probe (device).**  A non-F0 edge is provably non-MSF iff its
   endpoints connect through strictly lighter edges.  Two device
   certificates, both evaluated in the UPDATED graph's packed-key space
   (sound under weight ties, where re-keyed edge ids may flip old
   tie-breaks):

   * the quantized threshold-level probe of the filter pass — per-level
     fragment labels over F0 edges with key ≤ T_j, built by the
     warm-started :func:`repro.kernels.spmv_minplus.ops.connected_labels`
     hook/shortcut chain (level j refines level j-1's labels);
   * the packed max-key bound of
     :func:`repro.kernels.spmv_minplus.ops.component_maxkey` — the same
     loop warm-started from the top level's labels, returning each
     component's maximum tree key.  An edge inside one component whose key
     exceeds that bound exceeds its path max — the cycle rule's "swap
     against the max tree edge" test, with the swap resolved by the final
     solve over the kept candidates.

4. **Cut probe (device, same launch).**  Deleting a tree edge severs its
   component; replacement edges are exactly the non-F0 edges whose
   endpoints land in DIFFERENT F0 components (the probe's top-level
   labels).  They are never droppable by the cycle certificates, stay
   candidates, and the final solve elects the minimum crossing each cut —
   ``replacement_probes`` counts them.  One fused keep/cross-mask fetch is
   the single blocking readback of the whole update batch.
5. **Final solve.**  The Borůvka engine runs over the kept candidates
   (``F0`` + un-certified edges) via the §10 subset-graph path
   (``subgraph_by_mask`` / ``lift_mask`` keep the election order).  Since
   candidates ⊇ MSF(updated graph) and the MSF is unique under the packed
   (weight ‖ edge-id) total order, the lifted forest is bit-identical to
   the full re-solve — for every level count, shard count, and update mix.

:func:`plan_updates` / :func:`finalize_plan` split the pass around the
final solve so the serving layer (DESIGN.md §12) can batch many requests'
candidate solves through ``minimum_spanning_forests`` — each lane is
bit-identical to the single-graph solve, hence to :func:`apply_updates`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import boruvka_dist
from repro.core import keys as keys_lib
from repro.core import partition as partition_lib
from repro.core import runtime
from repro.core.filter_boruvka import _thresholds
from repro.core.graph import PAD_VERTEX, Graph, pair_ids, preprocess
from repro.core.kruskal_ref import ForestResult
from repro.core.params import DEFAULT_PARAMS, GHSParams
from repro.kernels.spmv_minplus import ops as minplus_ops
from repro.sharding import collectives


@dataclasses.dataclass(frozen=True)
class EdgeBatch:
    """One batched update: edge insertions (u, v, w) and deletions (u, v).

    Endpoints are vertex ids of the host graph (the vertex set is fixed —
    growing it is a new graph, not an update); insert weights must lie in
    the engines' (0, 1) range.  Deletions name canonical pairs — deleting
    a pair that is absent is a no-op, as is inserting a self-loop.  A pair
    both deleted and inserted in one batch is deleted from the OLD graph
    first, then re-inserted.
    """

    insert_src: np.ndarray     # (I,) int32
    insert_dst: np.ndarray     # (I,) int32
    insert_weight: np.ndarray  # (I,) float32, in (0, 1)
    delete_src: np.ndarray     # (D,) int32
    delete_dst: np.ndarray     # (D,) int32

    @classmethod
    def make(cls, inserts=(), deletes=()) -> "EdgeBatch":
        """Build from sequences of ``(u, v, w)`` / ``(u, v)`` tuples."""
        ins = np.asarray(list(inserts), dtype=np.float64).reshape(-1, 3)
        dels = np.asarray(list(deletes), dtype=np.int64).reshape(-1, 2)
        return cls(
            insert_src=ins[:, 0].astype(np.int32),
            insert_dst=ins[:, 1].astype(np.int32),
            insert_weight=ins[:, 2].astype(np.float32),
            delete_src=dels[:, 0].astype(np.int32),
            delete_dst=dels[:, 1].astype(np.int32),
        )

    @property
    def num_inserts(self) -> int:
        return int(self.insert_src.shape[0])

    @property
    def num_deletes(self) -> int:
        return int(self.delete_src.shape[0])

    @property
    def size(self) -> int:
        return self.num_inserts + self.num_deletes

    def validate(self, num_vertices: int) -> None:
        for a in (self.insert_src, self.insert_dst,
                  self.delete_src, self.delete_dst):
            if a.size and not (int(a.min()) >= 0
                               and int(a.max()) < num_vertices):
                raise ValueError(
                    f"update endpoints must lie in [0, {num_vertices})")
        w = self.insert_weight
        if w.size and not (float(w.min()) > 0.0 and float(w.max()) < 1.0):
            raise ValueError("insert weights must lie in (0, 1) — the "
                             "packed-key range of the engines (keys.py)")


@dataclasses.dataclass(frozen=True)
class IncrementalForest:
    """A solved graph: the handle :func:`apply_updates` evolves.

    ``forest.edge_mask`` indexes ``graph``'s canonical edges; after an
    update both are replaced (canonical ids shift when edges come and go),
    so hold on to the RETURNED handle, not the input one.
    """

    graph: Graph
    forest: ForestResult


@dataclasses.dataclass
class IncrementalStats(boruvka_dist.BatchStats):
    """Ledger of one :func:`apply_updates` batch.

    ``updates_applied`` / ``replacement_probes`` (runtime protocol) meter
    the update pass itself: structural changes actually applied (inserts
    that created or lightened an edge + deletes that removed one) and
    cut-probe candidates (non-tree edges crossing severed components).
    ``candidate_count`` is the final solve's edge count — the work the
    incremental pass did NOT skip; the sub-solve counters accumulate
    through the inherited :meth:`~repro.core.boruvka_dist.BatchStats.merge`.
    """

    candidate_count: int = 0


@dataclasses.dataclass(frozen=True)
class UpdatePlan:
    """The host-side residue of one probed update batch, ready for its
    final solve: the updated graph, the candidate subgraph (canonical
    subset — DESIGN.md §10 order contract), the lift index, and the probe
    ledger.  ``finalize_plan`` joins it with the candidate forest."""

    graph: Graph
    sub: Graph
    index: np.ndarray
    stats: IncrementalStats


def _canonical_pairs(src, dst, num_vertices: int) -> np.ndarray:
    u = np.minimum(src, dst).astype(np.int64)
    v = np.maximum(src, dst).astype(np.int64)
    return pair_ids(u, v, num_vertices)


def _apply_edge_batch_reference(graph: Graph, batch: EdgeBatch) -> Graph:
    """The DEFINITION of the updated graph: delete canonical pairs, then
    run everything back through §3.1 ``preprocess``.  Ties between an
    inserted copy and a surviving edge keep the survivor (the lexsort is
    stable and survivors precede inserts in the concatenation)."""
    n = graph.num_vertices
    keep = np.ones(graph.num_edges, dtype=bool)
    if batch.num_deletes:
        loops = batch.delete_src == batch.delete_dst
        dpid = np.unique(_canonical_pairs(
            batch.delete_src[~loops], batch.delete_dst[~loops], n))
        keep = ~np.isin(_canonical_pairs(graph.src, graph.dst, n), dpid)
    return preprocess(
        np.concatenate([graph.src[keep], batch.insert_src]),
        np.concatenate([graph.dst[keep], batch.insert_dst]),
        np.concatenate([graph.weight[keep], batch.insert_weight]),
        n)


def apply_edge_batch(graph: Graph, batch: EdgeBatch) -> Graph:
    """The updated graph — bit-identical to
    :func:`_apply_edge_batch_reference` (the preprocess-based definition),
    via a sorted merge: ``preprocess`` emits edges sorted by pair id, so
    deletions are a searchsorted mask and insertions splice in at their
    sorted positions — no O(m log m) lexsort of the whole survivor set per
    batch.  Collisions keep the minimum weight with ties going to the
    survivor, and duplicate inserts keep their first minimum copy, exactly
    matching the reference's stable sort.  A graph that is (somehow) not
    pair-sorted falls back to the reference path."""
    batch.validate(graph.num_vertices)
    n = graph.num_vertices
    pid = _canonical_pairs(graph.src, graph.dst, n)
    if pid.size and not bool(np.all(pid[1:] > pid[:-1])):
        return _apply_edge_batch_reference(graph, batch)
    src, dst, weight = graph.src, graph.dst, graph.weight

    if batch.num_deletes:
        loops = batch.delete_src == batch.delete_dst
        dpid = np.unique(_canonical_pairs(
            batch.delete_src[~loops], batch.delete_dst[~loops], n))
        if dpid.size:
            pos = np.searchsorted(dpid, pid)
            pos_c = np.minimum(pos, dpid.size - 1)
            keep = ~((pos < dpid.size) & (dpid[pos_c] == pid))
            src, dst = src[keep], dst[keep]
            weight, pid = weight[keep], pid[keep]

    if batch.num_inserts:
        iu = np.minimum(batch.insert_src, batch.insert_dst).astype(np.int64)
        iv = np.maximum(batch.insert_src, batch.insert_dst).astype(np.int64)
        iw = batch.insert_weight
        real = iu != iv                       # self-loops drop
        iu, iv, iw = iu[real], iv[real], iw[real]
        ipid = pair_ids(iu, iv, n)
        # Within-batch dedup: min weight per pair, first copy on weight
        # ties (np.lexsort is stable, matching the reference).
        order = np.lexsort((iw, ipid))
        ipid, iu, iv, iw = ipid[order], iu[order], iv[order], iw[order]
        first = np.ones(ipid.size, dtype=bool)
        first[1:] = ipid[1:] != ipid[:-1]
        ipid, iu, iv, iw = ipid[first], iu[first], iv[first], iw[first]
        # Collisions with survivors: strictly lighter inserts re-weight
        # the pair in place (ties keep the survivor).
        if pid.size:
            pos = np.searchsorted(pid, ipid)
            pos_c = np.minimum(pos, pid.size - 1)
            hit = (pos < pid.size) & (pid[pos_c] == ipid)
            lighter = hit & (iw < weight[pos_c])
            if lighter.any():
                weight = weight.copy()
                weight[pos_c[lighter]] = iw[lighter]
        else:
            pos = np.zeros(ipid.size, dtype=np.int64)
            hit = np.zeros(ipid.size, dtype=bool)
        # Fresh pairs splice in at their sorted positions.
        new = ~hit
        if new.any():
            at = pos[new]
            src = np.insert(src, at, iu[new].astype(np.int32))
            dst = np.insert(dst, at, iv[new].astype(np.int32))
            weight = np.insert(weight, at, iw[new])

    return Graph(num_vertices=n, src=src, dst=dst, weight=weight)


def _match_pairs(old: Graph, new: Graph) -> "tuple[np.ndarray, np.ndarray]":
    """Per-new-edge join against the old graph's canonical pairs:
    ``(hit, old_idx)`` with ``old_idx`` valid only where ``hit``.
    Canonical graphs are pair-sorted (``preprocess`` sorts by pair id),
    so the join is usually a direct searchsorted with no argsort."""
    pid_old = _canonical_pairs(old.src, old.dst, old.num_vertices)
    pid_new = _canonical_pairs(new.src, new.dst, old.num_vertices)
    if pid_old.size == 0:
        return (np.zeros(pid_new.size, dtype=bool),
                np.zeros(pid_new.size, dtype=np.int64))
    if bool(np.all(pid_old[1:] > pid_old[:-1])):
        order = None
        sorted_pid = pid_old
    else:
        order = np.argsort(pid_old, kind="stable")
        sorted_pid = pid_old[order]
    pos = np.searchsorted(sorted_pid, pid_new)
    pos_c = np.minimum(pos, sorted_pid.size - 1)
    hit = (pos < sorted_pid.size) & (sorted_pid[pos_c] == pid_new)
    return hit, (pos_c if order is None else order[pos_c])


def _anchor_tree_mask(old: IncrementalForest, new: Graph) -> np.ndarray:
    """F0 membership over the NEW graph's canonical edges: old tree pairs
    that survive with their weight unchanged (re-weighted pairs re-enter
    as probe candidates — their old certificates are void)."""
    if old.graph.num_edges == 0:
        return np.zeros(new.num_edges, dtype=bool)
    hit, old_idx = _match_pairs(old.graph, new)
    return hit & old.forest.edge_mask[old_idx] \
        & (new.weight == old.graph.weight[old_idx])


@functools.lru_cache(maxsize=None)
def _build_update_fns(num_vertices: int, mesh: Optional[Mesh],
                      use_pallas: bool, collective: str = "pmin",
                      cand_cap: Optional[int] = None):
    """Compiled (labels, probe) pair of the incremental pass.

    ``labels`` runs the warm-started threshold-level chain of the filter
    (level j's ``connected_labels`` inits from level j-1 — only newly
    activated tree edges pay hook iterations) and finishes with
    :func:`~repro.kernels.spmv_minplus.ops.component_maxkey` warm-started
    from the TOP level, whose threshold is the max tree key — so the
    max-key loop converges without iterating and only pays the packed
    scatter-max.  ``probe`` evaluates every candidate edge against all
    three certificates (level connectivity below key, component max-key
    bound, top-level component crossing) in one launch; under a mesh the
    tree arrays and probe edges run sharded with labels replicated, as in
    the filter pass.
    """
    n = num_vertices
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1

    def labels_fn(t_src, t_dst, t_key, thresholds, axis_name=None):
        comp, rows = None, []
        for j in range(thresholds.shape[0]):
            comp = minplus_ops.connected_labels(
                t_src, t_dst, t_key <= thresholds[j], num_vertices=n,
                init=comp, use_pallas=use_pallas, axis_name=axis_name,
                collective=collective, cand_cap=cand_cap,
                num_shards=num_shards)
            rows.append(comp)
        comp, maxkey = minplus_ops.component_maxkey(
            t_src, t_dst, t_key, t_key != keys_lib.INF_KEY,
            num_vertices=n, init=comp, use_pallas=use_pallas,
            axis_name=axis_name, collective=collective,
            cand_cap=cand_cap, num_shards=num_shards)
        return jnp.stack(rows), comp, maxkey

    def probe_fn(labels, comp, maxkey, thresholds, src, dst, key, tree):
        idx = jnp.searchsorted(thresholds, key, side="left")
        lvl = jnp.maximum(idx - 1, 0).astype(jnp.int64)
        u = jnp.clip(src, 0, n - 1).astype(jnp.int64)
        v = jnp.clip(dst, 0, n - 1).astype(jnp.int64)
        flat = labels.reshape(-1)
        below = (idx > 0) & (flat[lvl * n + u] == flat[lvl * n + v])
        joined = comp[u] == comp[v]
        over = joined & (key > maxkey[u])
        keep = tree | ~(below | over)
        cross = ~tree & ~joined & (key != keys_lib.INF_KEY)
        return keep, cross

    if mesh is not None:
        labels_fn = compat.shard_map(
            functools.partial(labels_fn, axis_name="x"), mesh,
            in_specs=(P("x"), P("x"), P("x"), P()),
            out_specs=(P(), P(), P()))
        probe_fn = compat.shard_map(
            probe_fn, mesh,
            in_specs=(P(), P(), P(), P(), P("x"), P("x"), P("x"), P("x")),
            out_specs=(P("x"), P("x")))
    return jax.jit(labels_fn), jax.jit(probe_fn)


def _pad_to(arrs, cap: int, fills):
    return tuple(
        np.concatenate([a, np.full(cap - a.size, f, a.dtype)])
        for a, f in zip(arrs, fills))


def _probe_candidates(g: Graph, tmask: np.ndarray, params: GHSParams,
                      mesh: Optional[Mesh]) -> "tuple[np.ndarray, int]":
    """(keep mask, cut-probe candidate count) over ``g``'s edges — the
    device half of the pass; ONE fused mask readback."""
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    n = g.num_vertices
    tree_pos = np.flatnonzero(tmask)
    key = g.packed_keys

    levels = int(params.update_levels) or int(params.filter_levels)
    thresholds = _thresholds(key[tree_pos], levels)

    t_block = partition_lib.pow2ceil(
        max(-(-max(tree_pos.size, 8) // num_shards), 1))
    t_cap = t_block * num_shards
    t_src, t_dst = _pad_to((g.src[tree_pos], g.dst[tree_pos]), t_cap,
                           (PAD_VERTEX, PAD_VERTEX))
    (t_key,) = _pad_to((key[tree_pos],), t_cap, (keys_lib.INF_KEY,))

    # Compressed hook-min exchange, gated exactly as in the filter pass
    # (DESIGN.md §11): engage when the wire model beats the dense pmin.
    collective = runtime.resolve_collective(params.collective)
    cand_cap = None
    if num_shards > 1 and collective == "compressed":
        cap = max(partition_lib.pow2ceil(min(n, 2 * t_block)), 8)
        if (collectives.compressed_bytes(cap, num_shards, 4)
                < collectives.dense_bytes(n, num_shards, 4)):
            cand_cap = cap

    m_cap = partition_lib.pow2ceil(max(g.num_edges, 8, num_shards))
    p_src, p_dst = _pad_to((g.src, g.dst), m_cap, (PAD_VERTEX, PAD_VERTEX))
    (p_key,) = _pad_to((key,), m_cap, (keys_lib.INF_KEY,))
    (p_tree,) = _pad_to((tmask,), m_cap, (False,))

    labels_fn, probe_fn = _build_update_fns(
        n, mesh, bool(params.use_pallas),
        "compressed" if cand_cap is not None else "pmin", cand_cap)
    with enable_x64():
        labels, comp, maxkey = labels_fn(
            jnp.asarray(t_src), jnp.asarray(t_dst), jnp.asarray(t_key),
            jnp.asarray(thresholds))
        keep_d, cross_d = probe_fn(
            labels, comp, maxkey, jnp.asarray(thresholds),
            jnp.asarray(p_src), jnp.asarray(p_dst), jnp.asarray(p_key),
            jnp.asarray(p_tree))
        keep, cross = jax.device_get((keep_d, cross_d))
    keep = np.asarray(keep, dtype=bool)[:g.num_edges]
    probes = int(np.asarray(cross, dtype=bool)[:g.num_edges].sum())
    return keep, probes


def plan_updates(
    state: IncrementalForest,
    batch: EdgeBatch,
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    updated: Optional[Graph] = None,
) -> UpdatePlan:
    """Merge + probe: everything in :func:`apply_updates` up to (not
    including) the final candidate solve.  ``updated`` optionally passes a
    precomputed :func:`apply_edge_batch` result (the serving layer merges
    at admission to route the bucket, then plans at flush)."""
    g2 = apply_edge_batch(state.graph, batch) if updated is None else updated
    stats = IncrementalStats()

    # Structural changes actually applied: pairs that vanished, appeared,
    # or changed weight (pairs are unique per graph, so the join counts
    # are exact).
    hit, old_idx = _match_pairs(state.graph, g2)
    removed = state.graph.num_edges - int(hit.sum())
    added = int((~hit).sum())
    if state.graph.num_edges == 0:
        changed = 0
        tmask = np.zeros(g2.num_edges, dtype=bool)
    else:
        same_w = g2.weight == state.graph.weight[old_idx]
        changed = int((hit & ~same_w).sum())
        # F0 (anchor) membership reuses the same join — see
        # _anchor_tree_mask for the standalone form.
        tmask = hit & state.forest.edge_mask[old_idx] & same_w
    stats.updates_applied = removed + added + changed
    if tmask.any():
        keep, probes = _probe_candidates(g2, tmask, params, mesh)
        stats.host_syncs += 1     # fused keep/cross-mask fetch
        stats.extra_syncs += 1
        stats.replacement_probes = probes
    else:
        # No anchor forest (empty or fully invalidated tree): no
        # certificates exist, the final solve sees every edge.
        keep = np.ones(g2.num_edges, dtype=bool)

    stats.edges_filtered = int(g2.num_edges - keep.sum())
    stats.filter_passes = 1
    sub, index = partition_lib.subgraph_by_mask(g2, keep)
    stats.candidate_count = sub.num_edges
    return UpdatePlan(graph=g2, sub=sub, index=index, stats=stats)


def finalize_plan(plan: UpdatePlan,
                  sub_forest: ForestResult) -> IncrementalForest:
    """Lift the candidate forest back to the updated graph's canonical
    edges (inverse of the §10 subset renumbering) — the new handle."""
    g2 = plan.graph
    mask = partition_lib.lift_mask(plan.index, sub_forest.edge_mask,
                                   g2.num_edges)
    forest = runtime.forest_from_mask(
        g2, mask, num_components=sub_forest.num_components)
    forest.check_consistent(g2.num_vertices)
    return IncrementalForest(graph=g2, forest=forest)


def apply_updates(
    state: IncrementalForest,
    batch: EdgeBatch,
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    max_rounds: Optional[int] = None,
) -> "tuple[IncrementalForest, IncrementalStats]":
    """Apply one insert/delete batch to a solved forest.

    Returns ``(new_state, stats)`` with ``new_state.forest`` bit-identical
    to a from-scratch solve of ``apply_edge_batch(state.graph, batch)``
    under any engine/params/mesh — the candidate set provably contains the
    updated MSF and the final solve is exact under the global packed-key
    order (module docstring).  ``stats`` carries the update ledger
    (``updates_applied``, ``replacement_probes``, ``candidate_count``)
    plus the final solve's counters via ``merge``.
    """
    plan = plan_updates(state, batch, params=params, mesh=mesh)
    res, st = boruvka_dist.minimum_spanning_forest(
        plan.sub, params=params, mesh=mesh, max_rounds=max_rounds)
    plan.stats.merge(st)
    return finalize_plan(plan, res), plan.stats
