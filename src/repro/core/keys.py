"""Packed 64-bit edge keys — the paper's weight + ``special_id`` tiebreak (C3/C6).

The GHS algorithm requires all edge weights to be distinct.  The paper (§3.2)
appends a ``special_id`` to the weight; §3.5 then compresses the message
encoding.  We adapt both ideas into a single sortable ``uint64``:

    key = (ieee754_bits(weight_f32) << 32) | unique_edge_id_32

Weights are in the open interval (0, 1), i.e. positive finite floats, whose
IEEE-754 bit patterns are monotonically ordered as unsigned integers.  The low
32 bits carry a globally unique edge id (the canonical edge index), so

  * ``min`` over keys == lexicographic min over (weight, tiebreak)  — GHS's
    distinct-weight precondition holds for ANY input weights, and
  * the comparison is a single integer ``min`` — VPU/MXU friendly, unlike the
    paper's 64-bit concatenated-vertex ``special_id`` which needs a second
    word.  (Adaptation note: this caps the graph at 2**32 canonical edges per
    key space; the paper's rank trick (§3.5 last paragraph) is superseded —
    see DESIGN.md §2.)

``INF_KEY`` (all ones) is the identity for min-reductions ("no outgoing
edge"), playing the role of the paper's Report(∞).

The optimized engine elects each fragment's minimum outgoing edge with ONE
segmented min over these packed keys (weight and tiebreak resolved in the
same reduction); kernels that must stay in 32-bit lanes split a key with
:func:`split_key_lanes` and compare lexicographically — the orders agree
bit-for-bit, which is what keeps every engine identical to the Kruskal
oracle.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Identity element for min-reductions over packed keys.
INF_KEY = np.uint64(0xFFFFFFFFFFFFFFFF)
# Any key with this weight-field is treated as "no edge".
INF_BITS = np.uint32(0xFFFFFFFF)

# splitmix64 constants — the ONE home for them (the counter-based pipeline
# RNG and the hashed partitioner both build on this finalizer; keeping a
# single copy keeps their streams from silently diverging).
SPLITMIX_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SPLITMIX_M1 = np.uint64(0xBF58476D1CE4E5B9)
_SPLITMIX_M2 = np.uint64(0x94D049BB133111EB)


def splitmix64(x):
    """splitmix64 finalizer over a uint64 array — identical arithmetic under
    numpy and jax.numpy (uint64 wraparound, operator-overloaded)."""
    z = x + SPLITMIX_GAMMA
    z = (z ^ (z >> np.uint64(30))) * _SPLITMIX_M1
    z = (z ^ (z >> np.uint64(27))) * _SPLITMIX_M2
    return z ^ (z >> np.uint64(31))


def pack_keys_np(weight: np.ndarray, edge_id: np.ndarray) -> np.ndarray:
    """numpy: pack float32 weights + uint32 edge ids into sortable uint64."""
    w = np.asarray(weight, dtype=np.float32)
    if np.any(w < 0):
        raise ValueError("packed keys require non-negative weights")
    bits = w.view(np.uint32).astype(np.uint64)
    eid = np.asarray(edge_id).astype(np.uint64) & np.uint64(0xFFFFFFFF)
    return (bits << np.uint64(32)) | eid


def unpack_weight_np(key: np.ndarray) -> np.ndarray:
    bits = (np.asarray(key, dtype=np.uint64) >> np.uint64(32)).astype(np.uint32)
    return bits.view(np.float32)


def unpack_edge_id_np(key: np.ndarray) -> np.ndarray:
    return (np.asarray(key, dtype=np.uint64) & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def pack_keys(weight: jnp.ndarray, edge_id: jnp.ndarray) -> jnp.ndarray:
    """jnp: pack float32 weights + int edge ids into sortable uint64."""
    bits = jax_f32_bits(weight).astype(jnp.uint64)
    eid = edge_id.astype(jnp.uint64) & jnp.uint64(0xFFFFFFFF)
    return (bits << jnp.uint64(32)) | eid


def unpack_edge_id(key: jnp.ndarray) -> jnp.ndarray:
    return (key & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)


def unpack_weight(key: jnp.ndarray) -> jnp.ndarray:
    bits = (key >> jnp.uint64(32)).astype(jnp.uint32)
    return jax_bits_f32(bits)


def jax_f32_bits(w: jnp.ndarray) -> jnp.ndarray:
    return jnp.asarray(w, jnp.float32).view(jnp.uint32)


def jax_bits_f32(bits: jnp.ndarray) -> jnp.ndarray:
    return jnp.asarray(bits, jnp.uint32).view(jnp.float32)


def split_key_lanes(key: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(weight-bits, edge-id) uint32 lanes of a packed key.  Lexicographic
    comparison of the lanes equals unsigned comparison of the uint64 key."""
    hi = (key >> jnp.uint64(32)).astype(jnp.uint32)
    lo = (key & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    return hi, lo


def combine_key_lanes(hi: jnp.ndarray, lo: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`split_key_lanes`."""
    return (hi.astype(jnp.uint64) << jnp.uint64(32)) | lo.astype(jnp.uint64)


def is_inf_key(key) -> np.ndarray:
    """True where a key denotes "no edge" (works for np and jnp arrays)."""
    return key == INF_KEY
