"""Filter-Borůvka sampling hybrid — expected-linear work (DESIGN.md §10).

Sample → solve → filter → solve, after Sanders & Schimek (*Engineering
Massively Parallel MST Algorithms*, PAPERS.md):

1. **Sample.**  A counter-based splitmix64 Bernoulli sample over canonical
   edge ids (:func:`repro.core.pipeline.sample_mask`) — a pure function of
   ``(pass, edge id)``, so the sample is byte-identical at any shard count
   and on either array namespace, like the graph generators.
2. **Solve the sample** with the existing Borůvka engine (every knob —
   partitioner, round_kernel, round_loop, mesh — composes unchanged).  Its
   forest ``F_S`` is the partial forest.
3. **Filter** (the cycle rule).  An edge ``e ∉ S`` is provably non-MSF iff
   its endpoints are connected in ``F_S`` using only tree edges with packed
   key strictly below ``key(e)`` — then ``e`` is the strict maximum of a
   cycle under the global (weight ‖ edge-id) total order of
   :mod:`repro.core.keys`, and the unique MSF excludes it.  Exact path
   maxima are priced out; instead the probe quantizes: sort the tree keys,
   take ``params.filter_levels`` quantile *thresholds* ``T_1 ≤ … ≤ T_K``,
   and build per-level fragment labels = connected components over tree
   edges with ``key ≤ T_j`` (one vmapped
   :func:`repro.kernels.spmv_minplus.ops.connected_labels` launch).  Drop
   ``e`` iff some ``T_j < key(e)`` connects its endpoints — since keys are
   globally distinct, connectivity at that level certifies a strictly
   lighter path.  Quantization only affects filter *efficiency* (how many
   droppable edges are recognized), never correctness.  Sampled non-tree
   edges are dropped outright (cycle property inside ``S ⊆ G``); sampled
   tree edges always survive.
4. **Final solve** over the survivors (partial forest included).  If the
   survivor count still exceeds ``params.filter_threshold`` (0 = auto,
   ``4·n``), one recursion — a second sample→solve→filter pass over the
   survivors under a fresh sample stream — runs first; never more
   (:data:`MAX_PASSES`).

Correctness is a subset sandwich: survivors always contain every MSF edge
(only provably-non-MSF edges are dropped) and are contained in the input,
and the MSF is unique under the packed-key total order — so the final
solve's forest is bit-identical to solving the full input, for every
sample rate, level count, and shard count.  The empty-sample guarantee is
the degenerate case: ``filter_sample_rate ≤ 0`` samples nothing, nothing
is filtered, and the final solve sees every edge.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import boruvka_dist
from repro.core import keys as keys_lib
from repro.core import partition as partition_lib
from repro.core import pipeline as pipeline_lib
from repro.core import runtime
from repro.core.graph import PAD_VERTEX, Graph
from repro.core.kruskal_ref import ForestResult
from repro.core.params import DEFAULT_PARAMS, GHSParams
from repro.kernels.spmv_minplus import ops as minplus_ops
from repro.sharding import collectives

MAX_PASSES = 2          # initial pass + the single recursion of DESIGN.md §10


@dataclasses.dataclass
class FilterStats(boruvka_dist.BatchStats):
    """Ledger of a filter-Borůvka run.

    ``edges_filtered`` / ``filter_passes`` (runtime protocol) meter the
    filter itself; the sub-solve counters (rounds, compactions, host syncs,
    …) accumulate across the sample and final solves through the inherited
    :meth:`~repro.core.boruvka_dist.BatchStats.merge`.
    ``survivor_history`` records the candidate count after each pass.
    """

    survivor_history: tuple = ()


def _thresholds(tree_keys: np.ndarray, num_levels: int) -> np.ndarray:
    """Ascending per-level key quantiles (upper edges) of the tree keys."""
    t_sorted = np.sort(tree_keys)
    t = t_sorted.size
    qi = (np.arange(1, num_levels + 1, dtype=np.int64) * t) // num_levels - 1
    return t_sorted[np.maximum(qi, 0)]


@functools.lru_cache(maxsize=None)
def _build_filter_fns(num_vertices: int, mesh: Optional[Mesh],
                      use_pallas: bool, collective: str = "pmin",
                      cand_cap: Optional[int] = None):
    """Compiled (labels, probe) pair for one vertex count.

    ``labels`` builds the (K, n) per-level fragment labels from the padded
    tree arrays — one vmapped converged-connectivity launch, K lanes
    sharing a single compiled while_loop.  Under a mesh it runs
    tree-edge-sharded (labels replicated), and ``collective``/``cand_cap``
    route its per-iteration hook-min through the compressed delta exchange
    (DESIGN.md §11; cand_cap is pow2 so the cache stays log-bounded).
    ``probe`` evaluates the quantized cycle rule for every candidate edge;
    under a mesh it runs edge-sharded with the labels replicated.
    """
    n = num_vertices
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1

    def labels_fn(t_src, t_dst, t_key, thresholds, axis_name=None):
        # Levels are nested (T_1 ≤ … ≤ T_K), so level j warm-starts from
        # level j-1's labels: only newly-activated tree edges pay hook
        # iterations, and the whole stack costs little more than one
        # converged solve.
        comp, rows = None, []
        for j in range(thresholds.shape[0]):
            comp = minplus_ops.connected_labels(
                t_src, t_dst, t_key <= thresholds[j], num_vertices=n,
                init=comp, use_pallas=use_pallas, axis_name=axis_name,
                collective=collective, cand_cap=cand_cap,
                num_shards=num_shards)
            rows.append(comp)
        return jnp.stack(rows)

    def probe_fn(labels, thresholds, src, dst, key, sampled, tree):
        # idx = #thresholds strictly below key(e): keys are globally
        # distinct from every tree key, so side="left" is a strict count.
        idx = jnp.searchsorted(thresholds, key, side="left")
        lvl = jnp.maximum(idx - 1, 0).astype(jnp.int64)
        u = jnp.clip(src, 0, n - 1).astype(jnp.int64)
        v = jnp.clip(dst, 0, n - 1).astype(jnp.int64)
        flat = labels.reshape(-1)
        below = (idx > 0) & (flat[lvl * n + u] == flat[lvl * n + v])
        return jnp.where(sampled, tree, ~below)

    if mesh is not None:
        labels_fn = compat.shard_map(
            functools.partial(labels_fn, axis_name="x"), mesh,
            in_specs=(P("x"), P("x"), P("x"), P()),
            out_specs=P())
        probe_fn = compat.shard_map(
            probe_fn, mesh,
            in_specs=(P(), P(), P("x"), P("x"), P("x"), P("x"), P("x")),
            out_specs=P("x"))
    return jax.jit(labels_fn), jax.jit(probe_fn)


def _pad_to(arrs, cap: int, fills):
    return tuple(
        np.concatenate([a, np.full(cap - a.size, f, a.dtype)])
        for a, f in zip(arrs, fills))


def _run_filter(g: Graph, cand: np.ndarray, tree_pos: np.ndarray,
                smask: np.ndarray, params: GHSParams,
                mesh: Optional[Mesh]) -> np.ndarray:
    """Keep-mask over ``cand`` from the quantized cycle rule (host glue)."""
    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    c_src, c_dst = g.src[cand], g.dst[cand]
    c_key = g.packed_keys[cand]
    tmask = np.zeros(cand.size, dtype=bool)
    tmask[tree_pos] = True

    thresholds = _thresholds(c_key[tree_pos], int(params.filter_levels))
    # Tree arrays are sharded under a mesh: a pow2 per-shard block keeps
    # every shard rectangular at any device count.
    t_block = partition_lib.pow2ceil(
        max(-(-max(tree_pos.size, 8) // num_shards), 1))
    t_cap = t_block * num_shards
    t_src, t_dst = _pad_to((c_src[tree_pos], c_dst[tree_pos]), t_cap,
                           (PAD_VERTEX, PAD_VERTEX))
    (t_key,) = _pad_to((c_key[tree_pos],), t_cap, (keys_lib.INF_KEY,))

    # Compressed hook-min exchange for the label loop (DESIGN.md §11):
    # each local tree edge can hook at most one entry per iteration, so
    # the per-shard block bounds the candidate count; engage only when the
    # wire model beats the dense uint32 pmin.
    n = g.num_vertices
    collective = runtime.resolve_collective(params.collective)
    cand_cap = None
    if num_shards > 1 and collective == "compressed":
        cap = max(partition_lib.pow2ceil(min(n, 2 * t_block)), 8)
        if (collectives.compressed_bytes(cap, num_shards, 4)
                < collectives.dense_bytes(n, num_shards, 4)):
            cand_cap = cap

    # Probe shape: power-of-two multiple of the shard count, padded with
    # INF keys (pad lanes resolve to "drop", then fall off the [:size]
    # slice below).
    m_cap = partition_lib.pow2ceil(max(cand.size, 8, num_shards))
    p_src, p_dst = _pad_to((c_src, c_dst), m_cap, (PAD_VERTEX, PAD_VERTEX))
    (p_key,) = _pad_to((c_key,), m_cap, (keys_lib.INF_KEY,))
    p_smp, p_tree = _pad_to((smask, tmask), m_cap, (False, False))

    labels_fn, probe_fn = _build_filter_fns(
        g.num_vertices, mesh, bool(params.use_pallas),
        "compressed" if cand_cap is not None else "pmin", cand_cap)
    with enable_x64():
        labels = labels_fn(jnp.asarray(t_src), jnp.asarray(t_dst),
                           jnp.asarray(t_key), jnp.asarray(thresholds))
        keep = probe_fn(labels, jnp.asarray(thresholds),
                        jnp.asarray(p_src), jnp.asarray(p_dst),
                        jnp.asarray(p_key), jnp.asarray(p_smp),
                        jnp.asarray(p_tree))
        keep = np.asarray(jax.device_get(keep), dtype=bool)[:cand.size]
    return keep


def minimum_spanning_forest(
    graph,
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    max_rounds: Optional[int] = None,
) -> tuple[ForestResult, FilterStats]:
    """Filter-Borůvka driver — same contract as the plain engine entry.

    ``graph`` is a host :class:`Graph` or a device-resident
    :class:`repro.core.pipeline.DeviceEdges`; the forest is bit-identical
    to ``method="boruvka"`` (and the Kruskal oracle) for every
    ``filter_sample_rate`` / ``filter_levels`` / shard count.
    """
    if not 1 <= int(params.filter_levels) <= 64:
        raise ValueError(
            f"filter_levels must be in [1, 64], got {params.filter_levels}")
    g = runtime.as_graph(graph)
    n, m = g.num_vertices, g.num_edges
    rate = float(params.filter_sample_rate)
    threshold = int(params.filter_threshold)
    if threshold <= 0:
        threshold = 4 * max(n, 1)

    stats = FilterStats()
    cand = np.arange(m, dtype=np.int64)          # canonical ids still in play

    for pass_idx in range(MAX_PASSES):
        smask = np.asarray(pipeline_lib.sample_mask(
            pass_idx, rate, cand.astype(np.uint64)), dtype=bool)
        s_pos = np.flatnonzero(smask)

        tree_pos = np.zeros(0, dtype=np.int64)
        if s_pos.size:
            # Canonical-subset order + monotone renumbering keep the
            # (weight, edge-id) election order, so the sample forest is the
            # true MSF of the sampled subgraph (partition.subgraph_by_mask
            # contract).
            sample_g = Graph(num_vertices=n, src=g.src[cand[s_pos]],
                             dst=g.dst[cand[s_pos]],
                             weight=g.weight[cand[s_pos]])
            f_s, st = boruvka_dist.minimum_spanning_forest(
                sample_g, params=params, mesh=mesh, max_rounds=max_rounds)
            stats.merge(st)
            tree_pos = s_pos[f_s.edge_mask]

        if tree_pos.size:
            keep = _run_filter(g, cand, tree_pos, smask, params, mesh)
            stats.host_syncs += 1      # keep-mask fetch inside _run_filter
            stats.extra_syncs += 1
        else:
            # Empty (or forest-free) sample: nothing is provably non-MSF,
            # so the final solve sees the full candidate set — the
            # empty-sample guarantee (DESIGN.md §10).
            keep = np.ones(cand.size, dtype=bool)

        stats.filter_passes += 1
        stats.edges_filtered += int(cand.size - keep.sum())
        cand = cand[keep]
        stats.survivor_history += (cand.size,)
        if cand.size <= threshold or not tree_pos.size or rate >= 1.0:
            break

    live = np.zeros(m, dtype=bool)
    live[cand] = True
    sub, index = partition_lib.subgraph_by_mask(g, live)
    res, st = boruvka_dist.minimum_spanning_forest(
        sub, params=params, mesh=mesh, max_rounds=max_rounds)
    stats.merge(st)

    forest = runtime.forest_from_mask(
        g, partition_lib.lift_mask(index, res.edge_mask, m),
        num_components=res.num_components)
    forest.check_consistent(n)
    return forest, stats
