"""Pluggable graph partitioners (DESIGN.md §7).

Both engines consume the partition choice through :mod:`repro.core.runtime`:

* The synchronous Borůvka engine distributes **edges** — a partitioner maps
  every canonical edge to a shard, and :func:`build_edge_layout` freezes that
  assignment into an :class:`EdgeLayout` (uniform per-shard slot blocks, slot
  → canonical-edge-id table).  The engine records tree edges by *slot*, so
  any layout yields the same forest; the layout only moves work around.
* The faithful GHS engine distributes **vertices** ("sequentially in blocks",
  paper §3).  A partitioner supplies a vertex *relabeling* permutation such
  that the engine's fixed block rule (`owner = new_id // block`) realizes the
  desired assignment.  Relabeling preserves edge order, weights, and
  canonical edge ids, so the elected forest is bit-identical for every
  partitioner — only message routing changes.

Partitioners (Sanders & Schimek: load balance, not the solver, decides
scaling at the top end):

* ``block``    — today's layout: contiguous slots / contiguous vertex ids.
* ``hashed``   — pseudo-random scatter (splitmix64), destroys skew hot-spots.
* ``balanced`` — degree/edge-balanced: edge blocks snap to source-vertex
  boundaries with ~equal edge counts; vertices snake-packed by degree so
  every shard holds ~the same adjacency volume.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import keys as keys_lib
from repro.core.graph import Graph


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 (keys.py — the shared finalizer) over uint64 ids."""
    return keys_lib.splitmix64(x.astype(np.uint64))


def pow2ceil(x: int) -> int:
    """Smallest power of two ≥ x (shared by layouts and engine buckets)."""
    p = 1
    while p < x:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class EdgeLayout:
    """Frozen edge→slot assignment: ``num_shards`` uniform blocks of
    ``block`` slots; ``eid[slot]`` is the canonical edge id held by that
    slot, or -1 for a padding slot."""

    num_shards: int
    block: int
    eid: np.ndarray            # (num_shards * block,) int64

    @property
    def num_slots(self) -> int:
        return self.num_shards * self.block

    def canonical_mask(self, slot_mask: np.ndarray, num_edges: int) -> np.ndarray:
        """Map a per-slot tree bitmap back to canonical edge ids."""
        slot_mask = np.asarray(slot_mask, dtype=bool)
        mask = np.zeros(num_edges, dtype=bool)
        sel = slot_mask & (self.eid >= 0)
        mask[self.eid[sel]] = True
        return mask


class Partitioner:
    """Partitioner contract — see module docstring and DESIGN.md §7."""

    name: str = "?"

    def edge_shard(self, graph: Graph, num_shards: int) -> np.ndarray:
        """(M,) int64 shard id per canonical edge."""
        raise NotImplementedError

    def vertex_perm(self, graph: Graph, num_shards: int) -> np.ndarray:
        """(N,) int64 new vertex id per old id; the engine's block rule
        (``owner = new_id // ceil(N / S)``) realizes the assignment, so the
        permutation must place ≤ ceil(N / S) vertices in each block."""
        raise NotImplementedError


class BlockPartitioner(Partitioner):
    """Today's layout: contiguous canonical-order blocks / identity labels."""

    name = "block"

    def edge_shard(self, graph: Graph, num_shards: int) -> np.ndarray:
        block = -(-graph.num_edges // num_shards) if graph.num_edges else 1
        return np.arange(graph.num_edges, dtype=np.int64) // block

    def vertex_perm(self, graph: Graph, num_shards: int) -> np.ndarray:
        return np.arange(graph.num_vertices, dtype=np.int64)


class HashedPartitioner(Partitioner):
    """Pseudo-random scatter of edges (by canonical id) and vertices."""

    name = "hashed"

    def edge_shard(self, graph: Graph, num_shards: int) -> np.ndarray:
        h = _mix64(np.arange(graph.num_edges, dtype=np.uint64))
        return (h % np.uint64(num_shards)).astype(np.int64)

    def vertex_perm(self, graph: Graph, num_shards: int) -> np.ndarray:
        n = graph.num_vertices
        order = np.argsort(_mix64(np.arange(n, dtype=np.uint64)),
                           kind="stable")
        perm = np.empty(n, dtype=np.int64)
        perm[order] = np.arange(n, dtype=np.int64)
        return perm


class BalancedPartitioner(Partitioner):
    """Degree/edge-balanced assignment.

    Edges: contiguous runs of the canonical (src-sorted) edge list with
    boundaries snapped to source-vertex starts, so no vertex's outgoing list
    is split while per-shard edge counts stay within one vertex's degree of
    even.  Vertices: snake-packed by descending degree — shard s's block
    collects every (2kS + s)-th and (2kS + 2S - 1 - s)-th heaviest vertex,
    equalizing adjacency volume per shard.
    """

    name = "balanced"

    def edge_shard(self, graph: Graph, num_shards: int) -> np.ndarray:
        m = graph.num_edges
        if m == 0:
            return np.zeros(0, dtype=np.int64)
        src = graph.src.astype(np.int64)
        # Start index of each distinct-src run (canonical edges sort by src).
        starts = np.flatnonzero(np.concatenate([[True], src[1:] != src[:-1]]))
        targets = (m * np.arange(num_shards, dtype=np.int64)) // num_shards
        # Snap each target boundary down to the run start at/before it.
        bounds = starts[np.searchsorted(starts, targets, side="right") - 1]
        bounds[0] = 0
        bounds = np.maximum.accumulate(bounds)
        return (np.searchsorted(bounds, np.arange(m), side="right")
                - 1).astype(np.int64)

    def vertex_perm(self, graph: Graph, num_shards: int) -> np.ndarray:
        n, S = graph.num_vertices, num_shards
        deg = np.zeros(n, dtype=np.int64)
        np.add.at(deg, graph.src, 1)
        np.add.at(deg, graph.dst, 1)
        heavy_first = np.argsort(-deg, kind="stable")
        # Walk the id space [0, S·block) column-major (one slot per shard
        # per round), snaking the shard order every other round, and keep
        # the ids < n — rank r (by descending degree) takes the r-th slot.
        # Respects the engine's block capacities exactly: when S ∤ n the
        # LAST block is short, and the invalid tail ids are simply never
        # handed out (the old shard·block+within formula leaked ids ≥ n).
        block = -(-n // S)
        rows = np.arange(S, dtype=np.int64)
        cols = np.arange(block, dtype=np.int64)
        snake = np.where(cols[:, None] % 2 == 0,
                         rows[None, :], rows[::-1][None, :])
        ids = (snake * block + cols[:, None]).ravel()   # column-major walk
        new_of_rank = ids[ids < n]
        perm = np.empty(n, dtype=np.int64)
        perm[heavy_first] = new_of_rank
        return perm


PARTITIONERS = {
    p.name: p for p in (BlockPartitioner(), HashedPartitioner(),
                        BalancedPartitioner())
}


def get_partitioner(name: str) -> Partitioner:
    try:
        return PARTITIONERS[name]
    except KeyError:
        raise ValueError(
            f"unknown partitioner {name!r}; options: "
            f"{tuple(PARTITIONERS)}") from None


def build_edge_layout(
    graph: Graph, partitioner: Partitioner, num_shards: int, chunk: int
) -> EdgeLayout:
    """Freeze an edge partition into uniform per-shard slot blocks.

    The ``block`` layout reproduces the engines' historical `_pad_pow2`
    shape exactly (global tail padding, power-of-two multiple of ``chunk``);
    other partitioners pad each shard independently to the max per-shard
    count (power of two, ≥ 8) so shapes stay rectangular for SPMD.
    """
    m = graph.num_edges
    if partitioner.name == "block":
        target = max(chunk, 1)
        while target < m:
            target *= 2
        eid = np.concatenate([
            np.arange(m, dtype=np.int64),
            np.full(target - m, -1, dtype=np.int64),
        ])
        return EdgeLayout(num_shards=num_shards,
                          block=target // num_shards, eid=eid)

    shard = partitioner.edge_shard(graph, num_shards)
    counts = np.bincount(shard, minlength=num_shards) if m else \
        np.zeros(num_shards, dtype=np.int64)
    block = pow2ceil(max(int(counts.max()) if m else 0,
                         max(chunk // num_shards, 8)))
    eid = np.full(num_shards * block, -1, dtype=np.int64)
    for s in range(num_shards):
        sel = np.flatnonzero(shard == s)       # ascending: canonical order
        eid[s * block: s * block + sel.size] = sel
    return EdgeLayout(num_shards=num_shards, block=block, eid=eid)


def identity_layout(num_edges: int, cap: int) -> EdgeLayout:
    """Single-shard layout whose slot *i* IS canonical edge *i*.

    This is the layout of every lane of a packed graph batch (DESIGN.md
    §8): edges are loaded in canonical order, the tail ≥ ``num_edges`` is
    padding — so a lane's winner bitmap maps back to canonical ids through
    the same :meth:`EdgeLayout.canonical_mask` path as the sharded engines.
    """
    eid = np.full(cap, -1, dtype=np.int64)
    eid[:num_edges] = np.arange(num_edges, dtype=np.int64)
    return EdgeLayout(num_shards=1, block=cap, eid=eid)


def batched_slots(batch_size: int, cap: int) -> np.ndarray:
    """(B, cap) int32 slot side-lane for a packed graph batch.

    Each lane carries its own slot index (the batched analogue of
    :class:`repro.core.runtime.EdgeBundle`'s per-shard ``slot`` lane), so
    tree-edge recording stays a local scatter under the batch axis and
    survives per-lane compaction exactly as it does per-shard.
    """
    return np.broadcast_to(
        np.arange(cap, dtype=np.int32), (batch_size, cap)).copy()


def subgraph_by_mask(graph: Graph, mask: np.ndarray) -> "tuple[Graph, np.ndarray]":
    """Canonical-order edge subset as its own :class:`Graph` (DESIGN.md §10).

    Returns ``(sub, index)`` where ``sub`` keeps every masked edge in
    canonical order and ``index[j]`` is the canonical edge id behind sub
    edge ``j``.  Because the subset preserves the canonical sort and the
    re-numbering ``j ↦ index[j]`` is strictly monotone, the (weight,
    edge-id) lexicographic election order of ``sub`` matches the original
    order restricted to the subset — an engine forest over ``sub`` is the
    restriction of the order-equivalent forest over the input.  This is
    how the filter pass re-partitions survivors: the subset graph flows
    through :func:`build_edge_layout` under ANY partitioner.
    """
    mask = np.asarray(mask, dtype=bool)
    index = np.flatnonzero(mask).astype(np.int64)
    sub = Graph(num_vertices=graph.num_vertices,
                src=graph.src[index], dst=graph.dst[index],
                weight=graph.weight[index])
    return sub, index


def lift_mask(index: np.ndarray, sub_mask: np.ndarray,
              num_edges: int) -> np.ndarray:
    """Map a subset-edge bitmap back to canonical edge ids
    (inverse of :func:`subgraph_by_mask`'s re-numbering)."""
    sub_mask = np.asarray(sub_mask, dtype=bool)
    mask = np.zeros(num_edges, dtype=bool)
    mask[index[sub_mask]] = True
    return mask


def relabel_graph(graph: Graph, perm: np.ndarray) -> Graph:
    """Apply a vertex relabeling WITHOUT touching edge order or weights.

    The returned graph's edge *i* is the same canonical edge *i* of the
    input (same weight, same packed key), with endpoints renamed — so any
    forest computed on it is directly a forest over the input's canonical
    edges.  Canonical ``src < dst`` is restored under the new labels.
    """
    perm = np.asarray(perm, dtype=np.int64)
    ps = perm[graph.src]
    pd = perm[graph.dst]
    return Graph(
        num_vertices=graph.num_vertices,
        src=np.minimum(ps, pd).astype(np.int32),
        dst=np.maximum(ps, pd).astype(np.int32),
        weight=graph.weight,
    )
