"""Graph generators used by the paper's evaluation (§4): RMAT, SSCA2, Uniform.

All follow the paper's conventions: ``SCALE`` = log2(num_vertices), average
vertex degree 32 (i.e. 16·N undirected edge samples), weights uniform in the
open interval (0, 1).  Generators return raw (possibly loop/multi-edge)
samples; callers run :func:`repro.core.graph.preprocess` (§3.1).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph, preprocess

_WEIGHT_EPS = np.float32(1e-9)


def _weights(rng: np.random.Generator, m: int) -> np.ndarray:
    w = rng.random(m, dtype=np.float32)
    # open interval (0, 1)
    return np.clip(w, _WEIGHT_EPS, np.float32(1.0) - _WEIGHT_EPS)


def rmat(
    scale: int,
    avg_degree: int = 32,
    *,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
) -> Graph:
    """R-MAT recursive-quadrant sampler (Chakrabarti et al., Graph500 params)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * avg_degree // 2
    d = 1.0 - a - b - c
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    p = np.array([a, b, c, d])
    cum = np.cumsum(p)
    for level in range(scale):
        r = rng.random(m)
        quad = np.searchsorted(cum, r, side="right").astype(np.int64)
        quad = np.minimum(quad, 3)
        src = (src << 1) | (quad >> 1)
        dst = (dst << 1) | (quad & 1)
    # Graph500-style vertex scrambling so low-id hubs are dispersed across the
    # block distribution (otherwise process 0 owns nearly all heavy vertices).
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    return preprocess(src, dst, _weights(rng, m), n)


def ssca2(
    scale: int,
    avg_degree: int = 32,
    *,
    seed: int = 0,
    max_clique: int | None = None,
) -> Graph:
    """SSCA2-style graph: randomly interconnected cliques (Bader & Madduri).

    Vertices are partitioned into cliques of size U[1, max_clique]; all
    intra-clique edges exist; consecutive cliques are linked by a few random
    inter-clique edges (guaranteeing the clique chain is connected, matching
    the benchmark's interconnection step).
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    if max_clique is None:
        # With all-pairs intra-clique edges, E[deg] ≈ (2/3)·max_clique for
        # uniform clique sizes; solve for the paper's avg degree 32.
        max_clique = max(2, int(avg_degree * 3 / 2))
    # Clique sizes: draw in batches until the prefix sum covers n, then cut
    # at the boundary (E[size] draws per batch keep this to O(1) rounds).
    sizes = np.zeros(0, dtype=np.int64)
    while int(sizes.sum()) < n:
        need = n - int(sizes.sum())
        batch = max(2 * need // (max_clique + 1) + 1, 16)
        sizes = np.concatenate(
            [sizes, rng.integers(1, max_clique + 1, size=batch)])
    cum = np.cumsum(sizes)
    n_cliques = int(np.searchsorted(cum, n, side="left")) + 1
    sizes = sizes[:n_cliques].copy()
    sizes[-1] -= int(cum[n_cliques - 1]) - n      # trim overshoot to n
    starts = np.concatenate([[0], np.cumsum(sizes[:-1])])
    # Intra-clique edges, grouped by clique size: all cliques of size s share
    # one triu template, broadcast over their start offsets — O(max_clique)
    # rounds instead of O(n_cliques) Python iterations.
    srcs, dsts = [], []
    for s in np.unique(sizes):
        if s < 2:
            continue
        u, v = np.triu_indices(int(s), k=1)
        s0 = starts[sizes == s]
        srcs.append((s0[:, None] + u[None, :]).ravel())
        dsts.append((s0[:, None] + v[None, :]).ravel())
    # Inter-clique links: clique i draws ``links_per`` uniformly chosen
    # earlier cliques (chain + chords) and a random endpoint on each side —
    # fully vectorized (uniform [0, k) via floor(U·k)).
    if n_cliques > 1:
        links_per = 3
        i = np.repeat(np.arange(1, n_cliques, dtype=np.int64), links_per)
        j = np.floor(rng.random(i.size) * i).astype(np.int64)
        u = starts[i] + np.floor(rng.random(i.size) * sizes[i]).astype(np.int64)
        v = starts[j] + np.floor(rng.random(i.size) * sizes[j]).astype(np.int64)
        srcs.append(u)
        dsts.append(v)
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    return preprocess(src, dst, _weights(rng, src.shape[0]), n)


def uniform_random(
    scale: int, avg_degree: int = 32, *, seed: int = 0
) -> Graph:
    """Erdős–Rényi-style G(n, m): endpoints chosen uniformly at random."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * avg_degree // 2
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return preprocess(src, dst, _weights(rng, m), n)


def disconnected(
    scale: int, components: int = 4, avg_degree: int = 8, *, seed: int = 0
) -> Graph:
    """Deliberately disconnected graph (forest test — paper §3.2 / C5)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    comp = max(1, components)
    size = n // comp
    srcs, dsts = [], []
    for ci in range(comp):
        base = ci * size
        sz = size if ci < comp - 1 else n - base
        if sz < 2:
            continue
        m = max(sz * avg_degree // 2, sz - 1)
        u = rng.integers(0, sz, size=m) + base
        v = rng.integers(0, sz, size=m) + base
        # a spanning path so each block is internally connected
        path = np.arange(base, base + sz - 1)
        srcs.extend([u, path])
        dsts.extend([v, path + 1])
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    return preprocess(src, dst, _weights(rng, src.shape[0]), n)


def _pipeline_kind(kind: str):
    """Host-oracle wrappers for the counter-based pipeline generators
    (geo_knn / grid / chain / star — see repro.core.pipeline)."""
    def gen(scale: int, avg_degree: int = 32, *, seed: int = 0) -> Graph:
        from repro.core import pipeline
        return pipeline.build_host(
            pipeline.GraphSpec(kind, scale, avg_degree=avg_degree, seed=seed))
    gen.__name__ = kind
    return gen


GENERATORS = {
    "rmat": rmat,
    "ssca2": ssca2,
    "random": uniform_random,
    "disconnected": disconnected,
    # New scenario generators (device pipeline's host oracle path).
    "geo_knn": _pipeline_kind("geo_knn"),
    "grid": _pipeline_kind("grid"),
    "chain": _pipeline_kind("chain"),
    "star": _pipeline_kind("star"),
}


def generate(kind: str, scale: int, **kw) -> Graph:
    return GENERATORS[kind](scale, **kw)
