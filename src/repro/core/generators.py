"""Graph generators used by the paper's evaluation (§4): RMAT, SSCA2, Uniform.

All follow the paper's conventions: ``SCALE`` = log2(num_vertices), average
vertex degree 32 (i.e. 16·N undirected edge samples), weights uniform in the
open interval (0, 1).  Generators return raw (possibly loop/multi-edge)
samples; callers run :func:`repro.core.graph.preprocess` (§3.1).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph, preprocess

_WEIGHT_EPS = np.float32(1e-9)


def _weights(rng: np.random.Generator, m: int) -> np.ndarray:
    w = rng.random(m, dtype=np.float32)
    # open interval (0, 1)
    return np.clip(w, _WEIGHT_EPS, np.float32(1.0) - _WEIGHT_EPS)


def rmat(
    scale: int,
    avg_degree: int = 32,
    *,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
) -> Graph:
    """R-MAT recursive-quadrant sampler (Chakrabarti et al., Graph500 params)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * avg_degree // 2
    d = 1.0 - a - b - c
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    p = np.array([a, b, c, d])
    cum = np.cumsum(p)
    for level in range(scale):
        r = rng.random(m)
        quad = np.searchsorted(cum, r, side="right").astype(np.int64)
        quad = np.minimum(quad, 3)
        src = (src << 1) | (quad >> 1)
        dst = (dst << 1) | (quad & 1)
    # Graph500-style vertex scrambling so low-id hubs are dispersed across the
    # block distribution (otherwise process 0 owns nearly all heavy vertices).
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    return preprocess(src, dst, _weights(rng, m), n)


def ssca2(
    scale: int,
    avg_degree: int = 32,
    *,
    seed: int = 0,
    max_clique: int | None = None,
) -> Graph:
    """SSCA2-style graph: randomly interconnected cliques (Bader & Madduri).

    Vertices are partitioned into cliques of size U[1, max_clique]; all
    intra-clique edges exist; consecutive cliques are linked by a few random
    inter-clique edges (guaranteeing the clique chain is connected, matching
    the benchmark's interconnection step).
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    if max_clique is None:
        # With all-pairs intra-clique edges, E[deg] ≈ (2/3)·max_clique for
        # uniform clique sizes; solve for the paper's avg degree 32.
        max_clique = max(2, int(avg_degree * 3 / 2))
    sizes = []
    total = 0
    while total < n:
        s = int(rng.integers(1, max_clique + 1))
        s = min(s, n - total)
        sizes.append(s)
        total += s
    starts = np.cumsum([0] + sizes[:-1])
    srcs, dsts = [], []
    for s0, sz in zip(starts, sizes):
        if sz > 1:
            u, v = np.triu_indices(sz, k=1)
            srcs.append(u + s0)
            dsts.append(v + s0)
    # Inter-clique links: connect clique i to a uniformly chosen earlier clique
    # (chain + chords), a few links each.
    n_cliques = len(sizes)
    if n_cliques > 1:
        links_per = 3
        for i in range(1, n_cliques):
            js = rng.integers(0, i, size=links_per)
            for j in js:
                u = starts[i] + rng.integers(0, sizes[i])
                v = starts[j] + rng.integers(0, sizes[j])
                srcs.append(np.array([u]))
                dsts.append(np.array([v]))
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    return preprocess(src, dst, _weights(rng, src.shape[0]), n)


def uniform_random(
    scale: int, avg_degree: int = 32, *, seed: int = 0
) -> Graph:
    """Erdős–Rényi-style G(n, m): endpoints chosen uniformly at random."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * avg_degree // 2
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return preprocess(src, dst, _weights(rng, m), n)


def disconnected(
    scale: int, components: int = 4, avg_degree: int = 8, *, seed: int = 0
) -> Graph:
    """Deliberately disconnected graph (forest test — paper §3.2 / C5)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    comp = max(1, components)
    size = n // comp
    srcs, dsts = [], []
    for ci in range(comp):
        base = ci * size
        sz = size if ci < comp - 1 else n - base
        if sz < 2:
            continue
        m = max(sz * avg_degree // 2, sz - 1)
        u = rng.integers(0, sz, size=m) + base
        v = rng.integers(0, sz, size=m) + base
        # a spanning path so each block is internally connected
        path = np.arange(base, base + sz - 1)
        srcs.extend([u, path])
        dsts.extend([v, path + 1])
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    return preprocess(src, dst, _weights(rng, src.shape[0]), n)


GENERATORS = {
    "rmat": rmat,
    "ssca2": ssca2,
    "random": uniform_random,
    "disconnected": disconnected,
}


def generate(kind: str, scale: int, **kw) -> Graph:
    return GENERATORS[kind](scale, **kw)
