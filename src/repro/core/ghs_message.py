"""Paper-faithful message-driven GHS engine (Mazeev et al. 2016).

Executes the original GHS vertex procedures (Gallager–Humblet–Spira 1983,
handlers (1)-(11)) under the paper's implementation scheme (§3.2):

    While (True) {
        read_msgs();                   -> ingest()       (vectorized)
        process_queue();               -> sequential pop/dispatch loop
        [every CHECK_FREQUENCY steps]  -> drain the separate Test queue (C1)
        send_all_bufs();               -> flush() + all_to_all  (C4)
        check_finish();                -> psum silence detection (C5)
    }

Each MPI process of the paper maps to one device shard (shard_map over axis
"x"); vertices are block-distributed; per-destination aggregation buffers map
to fixed-capacity buckets exchanged with ONE fused all_to_all per superstep.
Messages are bit-packed uint32 lanes (C3); incoming messages locate their edge
via the linear-probe hash (C2) or the linear/binary-search ablations.  Under
the hash variant the whole inbox is edge-resolved in ONE vectorized probe
sweep (the ``kernels/edge_hash`` batched op) before the sequential dispatch
loop; resolved positions ride a side-lane of the local queue rings.

The superstep loop itself is device-resident (DESIGN.md §6): a
``jax.lax.while_loop`` advances up to ``check_frequency`` supersteps per
dispatch, counting consecutive silent psum checks on device
(``empty_iter_cnt_to_break``, paper §3.6), so the host synchronizes once per
interval — not twice per superstep as the legacy driver
(``params.round_loop == "host"``, retained as the before/after baseline)
does.  Both drivers run through :mod:`repro.core.runtime`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import runtime
from repro.core.ghs_state import (
    ACCEPT, BASIC, BRANCH, CHANGE_CORE, CONNECT, FIND, FOUND, INITIATE,
    POS_UNRESOLVED, REJECT, REJECTED, REPORT, TEST, GHSTopology, ShardState,
    hash_slot, init_shards, stack_shards,
)
from repro.core.graph import Graph
from repro.core.kruskal_ref import ForestResult
from repro.core.params import DEFAULT_PARAMS, GHSParams
from repro.kernels.edge_hash import ops as edge_ops

INF32 = jnp.uint32(0xFFFFFFFF)
_AXIS = "x"

ERR_QUEUE_OVERFLOW = 1
ERR_HASH_MISS = 2
ERR_LOGIC = 4


@dataclasses.dataclass
class GHSStats(runtime.EngineStats):
    supersteps: int = 0
    processed: int = 0
    productive: int = 0
    sent_remote: int = 0
    sent_local: int = 0
    halted_fragments: int = 0
    bytes_remote: int = 0
    # per-superstep histories (Fig 3 / Fig 4 analogues)
    queue_history: tuple = ()
    bytes_history: tuple = ()


# ---------------------------------------------------------------------------
# Superstep builder
# ---------------------------------------------------------------------------

def make_superstep(topo: GHSTopology, params: GHSParams, axis_name):
    """Returns superstep(st, do_test, gstep) -> (st, activity, err), traced
    for one shard.  ``do_test`` (traced bool) selects the Test-queue drain;
    ``gstep`` (traced i32) is the global superstep index used for the
    on-device history buffers."""
    S = topo.num_shards
    block = topo.block
    qcap, ocap, xcap = topo.qcap, topo.ocap, topo.xcap
    tsize, lanes = topo.tsize, topo.lanes
    relaxed = bool(params.relaxed_test_queue)
    method = ("hash" if params.use_hashing else "linear")
    if not params.use_hashing and params.hash_table_factor < 0:
        method = "binary"   # sentinel: factor<0 selects binary ablation
    compressed = lanes == 5

    # --- message encode/decode -------------------------------------------
    def encode(mtype, level, state, src, dst, fw, fe):
        u = lambda x: jnp.asarray(x).astype(jnp.uint32)
        if compressed:
            hdr = u(mtype) | (u(state) << 3) | (u(level) << 4)
            return jnp.stack([hdr, u(src), u(dst), u(fw), u(fe)])
        return jnp.stack([u(mtype), u(level), u(state), u(src), u(dst),
                          u(fw), u(fe), jnp.uint32(0)])

    def decode(msg):
        if compressed:
            hdr = msg[0]
            return (hdr & 7, hdr >> 4, (hdr >> 3) & 1,
                    msg[1], msg[2], msg[3], msg[4])
        return (msg[0], msg[1], msg[2], msg[3], msg[4], msg[5], msg[6])

    def msg_type(rows):  # vectorized, for ingest routing
        return (rows[:, 0] & 7) if compressed else rows[:, 0]

    def msg_src_dst(rows):  # vectorized, for the batched edge pre-pass
        return (rows[:, 1], rows[:, 2]) if compressed else (rows[:, 3],
                                                            rows[:, 4])

    def less(w1, e1, w2, e2):
        return (w1 < w2) | ((w1 == w2) & (e1 < e2))

    # --- queue push (masked, branch-free) ---------------------------------
    def push(st: ShardState, msg, dst, my_shard, pred, is_test, pos=None):
        posv = jnp.asarray(POS_UNRESOLVED if pos is None else pos, jnp.int32)
        ds = (dst.astype(jnp.int32) // block)
        local = (ds == my_shard) & pred
        lm = local & ~is_test
        lt = local & is_test
        rm = pred & ~(ds == my_shard)
        # local main queue
        idx = jnp.where(lm, (st.mq_tail % qcap).astype(jnp.int32), qcap)
        mq = st.mq.at[idx].set(msg, mode="drop")
        mq_pos = st.mq_pos.at[idx].set(posv, mode="drop")
        mq_tail = st.mq_tail + lm.astype(jnp.int32)
        # local test queue
        idx = jnp.where(lt, (st.tq_tail % qcap).astype(jnp.int32), qcap)
        tq = st.tq.at[idx].set(msg, mode="drop")
        tq_pos = st.tq_pos.at[idx].set(posv, mode="drop")
        tq_tail = st.tq_tail + lt.astype(jnp.int32)
        # remote ring
        row = jnp.where(rm, ds, S)
        col = jnp.where(rm, (st.og_tail[ds % S] % ocap).astype(jnp.int32),
                        ocap)
        og = st.og.at[row, col].set(msg, mode="drop")
        og_tail = st.og_tail.at[ds % S].add(rm.astype(jnp.int32))
        err = st.err | jnp.where(
            (mq_tail - st.mq_head > qcap) | (tq_tail - st.tq_head > qcap)
            | jnp.any(og_tail - st.og_head > ocap),
            ERR_QUEUE_OVERFLOW, 0).astype(jnp.int32)
        return st._replace(
            mq=mq, mq_pos=mq_pos, mq_tail=mq_tail,
            tq=tq, tq_pos=tq_pos, tq_tail=tq_tail,
            og=og, og_tail=og_tail, err=err,
            n_sent_local=st.n_sent_local + local.astype(jnp.int32),
            n_sent_remote=st.n_sent_remote + rm.astype(jnp.int32),
        )

    def send(st, my_shard, mtype, level, state, src, dst, fw, fe, pred):
        msg = encode(mtype, level, state, src, dst, fw, fe)
        is_test = jnp.asarray(relaxed and mtype == TEST)
        return push(st, msg, dst, my_shard, pred, is_test)

    # --- edge lookup (C2 + ablations) -------------------------------------
    def lookup(st: ShardState, lv, u):
        if method == "hash":
            h0 = hash_slot(lv, u, tsize)

            def cond(c):
                _, done, steps = c
                return (~done) & (steps < tsize)

            def body(c):
                h, _, steps = c
                hit = (st.h_lv[h] == lv) & (st.h_u[h] == u)
                empty = st.h_pos[h] < 0
                return ((h + 1) % tsize, hit | empty, steps + 1)

            h, _, _ = jax.lax.while_loop(cond, body, (h0, jnp.bool_(False),
                                                      jnp.int32(0)))
            h = (h - 1) % tsize
            hit = (st.h_lv[h] == lv) & (st.h_u[h] == u)
            p = jnp.where(hit, st.h_pos[h], -1)
            return p
        a = st.indptr[lv]
        b = st.indptr[lv + 1]
        if method == "linear":
            def cond(c):
                q, found = c
                return (~found) & (q < b)

            def body(c):
                q, _ = c
                return jnp.where(st.nbr[q] == u, q, q + 1), st.nbr[q] == u

            q, found = jax.lax.while_loop(cond, body, (a, jnp.bool_(False)))
            return jnp.where(found, q, -1)
        # binary search over the by-neighbor-id permutation
        def bcond(c):
            lo, hi = c
            return lo < hi

        def bbody(c):
            lo, hi = c
            mid = (lo + hi) // 2
            v = st.nbr[st.byid[mid]]
            return jnp.where(v < u, mid + 1, lo), jnp.where(v < u, hi, mid)

        lo, _ = jax.lax.while_loop(bcond, bbody, (a, b))
        ok = (lo < b) & (st.nbr[st.byid[lo]] == u)
        return jnp.where(ok, st.byid[lo], -1)

    # --- GHS procedures ----------------------------------------------------
    def key_of(st, p):
        return st.ewb[p], st.etb[p]

    def report_proc(st: ShardState, my_shard, lv, pred):
        """GHS (8): if find_count==0 and test_edge==nil, report up in_branch."""
        ib = st.in_branch[lv]
        fire = pred & (st.find_count[lv] == 0) & (st.test_edge[lv] == -1) \
            & (ib >= 0)
        ibq = jnp.maximum(ib, 0)
        st = st._replace(sn=st.sn.at[lv].set(
            jnp.where(fire, FOUND, st.sn[lv])))
        return send(st, my_shard, REPORT, st.ln[lv], 0, block * my_shard + lv,
                    st.nbr[ibq], st.best_w[lv], st.best_e[lv], fire)

    def change_core(st: ShardState, my_shard, lv, pred):
        """GHS (10)."""
        be = st.best_edge[lv]
        valid = pred & (be >= 0)
        beq = jnp.maximum(be, 0)
        on_branch = st.se[beq] == BRANCH
        vme = block * my_shard + lv
        st = send(st, my_shard, CHANGE_CORE, 0, 0, vme, st.nbr[beq], 0, 0,
                  valid & on_branch)
        st = send(st, my_shard, CONNECT, st.ln[lv], 0, vme, st.nbr[beq], 0, 0,
                  valid & ~on_branch)
        se = st.se.at[beq].set(
            jnp.where(valid & ~on_branch, BRANCH, st.se[beq]))
        err = st.err | jnp.where(pred & (be < 0), ERR_LOGIC, 0).astype(
            jnp.int32)
        return st._replace(se=se, err=err)

    def test_proc(st: ShardState, my_shard, lv):
        """GHS (4): probe lightest Basic edge or report."""
        a = st.indptr[lv]
        b = st.indptr[lv + 1]

        def cond(c):
            q, found = c
            return (~found) & (q < b)

        def body(c):
            q, _ = c
            isb = st.se[q] == BASIC
            return jnp.where(isb, q, q + 1), isb

        q, found = jax.lax.while_loop(cond, body, (a, jnp.bool_(False)))
        qq = jnp.minimum(q, b - 1)
        st = st._replace(test_edge=st.test_edge.at[lv].set(
            jnp.where(found, q, -1)))
        st = send(st, my_shard, TEST, st.ln[lv], 0, block * my_shard + lv,
                  st.nbr[qq], st.fnw[lv], st.fne[lv], found)
        return report_proc(st, my_shard, lv, ~found)

    # --- handlers (uniform signature) --------------------------------------
    # args: st, my_shard, u, lv, p, level, state_bit, fw, fe, raw_msg
    def h_connect(st, my_shard, u, lv, p, level, state_bit, fw, fe, raw):
        vme = block * my_shard + lv
        absorb = level < st.ln[lv]
        merge = ~absorb & (st.se[p] != BASIC)
        postpone = ~absorb & (st.se[p] == BASIC)
        se = st.se.at[p].set(jnp.where(absorb, BRANCH, st.se[p]))
        st = st._replace(se=se)
        im_find = st.sn[lv] == FIND
        st = send(st, my_shard, INITIATE, st.ln[lv],
                  jnp.where(im_find, 1, 0), vme, u, st.fnw[lv], st.fne[lv],
                  absorb)
        st = st._replace(find_count=st.find_count.at[lv].add(
            jnp.where(absorb & im_find, 1, 0)))
        kw, ke = key_of(st, p)
        st = send(st, my_shard, INITIATE, st.ln[lv] + 1, 1, vme, u, kw, ke,
                  merge)
        st = push(st, raw, jnp.asarray(vme, jnp.uint32), my_shard, postpone,
                  jnp.bool_(False), pos=p)
        return st, ~postpone

    def h_initiate(st, my_shard, u, lv, p, level, state_bit, fw, fe, raw):
        vme = block * my_shard + lv
        st = st._replace(
            ln=st.ln.at[lv].set(level.astype(jnp.uint32)),
            fnw=st.fnw.at[lv].set(fw), fne=st.fne.at[lv].set(fe),
            sn=st.sn.at[lv].set(jnp.where(state_bit == 1, FIND, FOUND)),
            in_branch=st.in_branch.at[lv].set(p),
            best_edge=st.best_edge.at[lv].set(-1),
            best_w=st.best_w.at[lv].set(INF32),
            best_e=st.best_e.at[lv].set(INF32),
        )
        a = st.indptr[lv]
        b = st.indptr[lv + 1]

        def body(c):
            q, st = c
            fwd = (st.se[q] == BRANCH) & (q != p)
            st = send(st, my_shard, INITIATE, level, state_bit, vme,
                      st.nbr[q], fw, fe, fwd)
            st = st._replace(find_count=st.find_count.at[lv].add(
                jnp.where(fwd & (state_bit == 1), 1, 0)))
            return q + 1, st

        _, st = jax.lax.while_loop(lambda c: c[0] < b, body, (a, st))
        st = jax.lax.cond(state_bit == 1,
                          lambda s: test_proc(s, my_shard, lv),
                          lambda s: s, st)
        return st, jnp.bool_(True)

    def h_test(st, my_shard, u, lv, p, level, state_bit, fw, fe, raw):
        vme = block * my_shard + lv
        postpone = level > st.ln[lv]
        same = (fw == st.fnw[lv]) & (fe == st.fne[lv])
        accept = ~postpone & ~same
        rej = ~postpone & same
        st = send(st, my_shard, ACCEPT, 0, 0, vme, u, 0, 0, accept)
        se = st.se.at[p].set(
            jnp.where(rej & (st.se[p] == BASIC), REJECTED, st.se[p]))
        st = st._replace(se=se)
        was_testing = st.test_edge[lv] == p
        st = send(st, my_shard, REJECT, 0, 0, vme, u, 0, 0,
                  rej & ~was_testing)
        st = jax.lax.cond(rej & was_testing,
                          lambda s: test_proc(s, my_shard, lv),
                          lambda s: s, st)
        st = push(st, raw, jnp.asarray(vme, jnp.uint32), my_shard, postpone,
                  jnp.bool_(relaxed), pos=p)
        return st, ~postpone

    def h_accept(st, my_shard, u, lv, p, level, state_bit, fw, fe, raw):
        st = st._replace(test_edge=st.test_edge.at[lv].set(-1))
        w, e = key_of(st, p)
        better = less(w, e, st.best_w[lv], st.best_e[lv])
        st = st._replace(
            best_edge=st.best_edge.at[lv].set(
                jnp.where(better, p, st.best_edge[lv])),
            best_w=st.best_w.at[lv].set(jnp.where(better, w, st.best_w[lv])),
            best_e=st.best_e.at[lv].set(jnp.where(better, e, st.best_e[lv])),
        )
        st = report_proc(st, my_shard, lv, jnp.bool_(True))
        return st, jnp.bool_(True)

    def h_reject(st, my_shard, u, lv, p, level, state_bit, fw, fe, raw):
        se = st.se.at[p].set(
            jnp.where(st.se[p] == BASIC, REJECTED, st.se[p]))
        st = test_proc(st._replace(se=se), my_shard, lv)
        return st, jnp.bool_(True)

    def h_report(st, my_shard, u, lv, p, level, state_bit, fw, fe, raw):
        vme = block * my_shard + lv
        noncore = p != st.in_branch[lv]
        # non-core: aggregate child report
        st = st._replace(find_count=st.find_count.at[lv].add(
            jnp.where(noncore, -1, 0)))
        better = noncore & less(fw, fe, st.best_w[lv], st.best_e[lv])
        st = st._replace(
            best_edge=st.best_edge.at[lv].set(
                jnp.where(better, p, st.best_edge[lv])),
            best_w=st.best_w.at[lv].set(
                jnp.where(better, fw, st.best_w[lv])),
            best_e=st.best_e.at[lv].set(
                jnp.where(better, fe, st.best_e[lv])),
        )
        st = report_proc(st, my_shard, lv, noncore)
        # core: decide winner side / halt
        postpone = ~noncore & (st.sn[lv] == FIND)
        my_smaller = less(st.best_w[lv], st.best_e[lv], fw, fe)
        st = change_core(st, my_shard, lv, ~noncore & ~postpone & my_smaller)
        halt = (~noncore & ~postpone & ~my_smaller
                & (fw == INF32) & (fe == INF32)
                & (st.best_w[lv] == INF32) & (st.best_e[lv] == INF32))
        st = st._replace(halted=st.halted + halt.astype(jnp.int32))
        st = push(st, raw, jnp.asarray(vme, jnp.uint32), my_shard, postpone,
                  jnp.bool_(False), pos=p)
        return st, ~postpone

    def h_changecore(st, my_shard, u, lv, p, level, state_bit, fw, fe, raw):
        st = change_core(st, my_shard, lv, jnp.bool_(True))
        return st, jnp.bool_(True)

    handlers = [h_connect, h_initiate, h_test, h_accept, h_reject, h_report,
                h_changecore]

    # --- dispatch one message ---------------------------------------------
    def dispatch(st: ShardState, my_shard, raw, pre):
        """``pre`` is the batch-resolved CSR position side-lane value: >= 0
        skips the scalar probe entirely; POS_UNRESOLVED falls back to it."""
        mtype, level, state_bit, src, dst, fw, fe = decode(raw)
        lv = (dst.astype(jnp.int32) - block * my_shard)
        u = src.astype(jnp.int32)
        p = jax.lax.cond(pre >= 0, lambda: pre, lambda: lookup(st, lv, u))
        err = st.err | jnp.where(p < 0, ERR_HASH_MISS, 0).astype(jnp.int32)
        st = st._replace(err=err)
        p = jnp.maximum(p, 0)
        st, productive = jax.lax.switch(
            jnp.clip(mtype.astype(jnp.int32), 0, 6),
            handlers, st, my_shard, u, lv, p, level, state_bit, fw, fe, raw)
        return st._replace(
            n_processed=st.n_processed + 1,
            n_productive=st.n_productive + productive.astype(jnp.int32))

    # --- queue processing ----------------------------------------------------
    def process_main(st: ShardState, my_shard):
        # Budget: the queue snapshot plus slack so freshly-generated local
        # messages (e.g. ChangeCore chains) advance several hops per
        # superstep; bounded so postponed-message spins cannot livelock.
        budget = 2 * (st.mq_tail - st.mq_head) + 64

        def cond(c):
            st, n = c
            return (st.mq_head < st.mq_tail) & (n < budget) & (st.err == 0)

        def body(c):
            st, n = c
            slot = (st.mq_head % qcap).astype(jnp.int32)
            raw = st.mq[slot]
            pre = st.mq_pos[slot]
            st = st._replace(mq_head=st.mq_head + 1)
            return dispatch(st, my_shard, raw, pre), n + 1

        st, _ = jax.lax.while_loop(cond, body, (st, jnp.int32(0)))
        return st

    def process_test_q(st: ShardState, my_shard):
        snapshot = st.tq_tail

        def cond(c):
            st, n = c
            return (st.tq_head < snapshot) & (st.err == 0)

        def body(c):
            st, n = c
            slot = (st.tq_head % qcap).astype(jnp.int32)
            raw = st.tq[slot]
            pre = st.tq_pos[slot]
            st = st._replace(tq_head=st.tq_head + 1)
            return dispatch(st, my_shard, raw, pre), n + 1

        st, _ = jax.lax.while_loop(cond, body, (st, jnp.int32(0)))
        return st

    # --- ingest & flush ------------------------------------------------------
    def ingest(st: ShardState, my_shard):
        flat = st.inbox.reshape(S * xcap, lanes)
        valid = (jnp.arange(xcap)[None, :]
                 < st.in_cnt[:, None]).reshape(-1)
        if method == "hash":
            # Batched pre-pass (C2, vectorized): resolve every incoming
            # message's edge in one lock-step probe sweep over the shard's
            # hash table instead of one scalar probe chain per pop.
            srcs, dsts = msg_src_dst(flat)
            qlv = dsts.astype(jnp.int32) - block * my_shard
            pre = edge_ops.resolve_batch(
                st.h_lv, st.h_u, st.h_pos, qlv, srcs, valid,
                max_probes=min(tsize, 64))
            pre = jnp.where(pre >= 0, pre, jnp.int32(POS_UNRESOLVED))
        else:
            pre = jnp.full(flat.shape[0], POS_UNRESOLVED, jnp.int32)
        istest = jnp.asarray(relaxed) & (msg_type(flat) == TEST)
        to_main = valid & ~istest
        to_test = valid & istest
        pos = st.mq_tail + jnp.cumsum(to_main.astype(jnp.int32)) - 1
        idx = jnp.where(to_main, (pos % qcap).astype(jnp.int32), qcap)
        mq = st.mq.at[idx].set(flat, mode="drop")
        mq_pos = st.mq_pos.at[idx].set(pre, mode="drop")
        mq_tail = st.mq_tail + to_main.sum(dtype=jnp.int32)
        pos = st.tq_tail + jnp.cumsum(to_test.astype(jnp.int32)) - 1
        idx = jnp.where(to_test, (pos % qcap).astype(jnp.int32), qcap)
        tq = st.tq.at[idx].set(flat, mode="drop")
        tq_pos = st.tq_pos.at[idx].set(pre, mode="drop")
        tq_tail = st.tq_tail + to_test.sum(dtype=jnp.int32)
        err = st.err | jnp.where(
            (mq_tail - st.mq_head > qcap) | (tq_tail - st.tq_head > qcap),
            ERR_QUEUE_OVERFLOW, 0).astype(jnp.int32)
        return st._replace(mq=mq, mq_pos=mq_pos, mq_tail=mq_tail,
                           tq=tq, tq_pos=tq_pos, tq_tail=tq_tail,
                           in_cnt=jnp.zeros_like(st.in_cnt), err=err)

    def flush(st: ShardState):
        avail = st.og_tail - st.og_head
        k = jnp.minimum(avail, xcap)
        cols = ((st.og_head[:, None] + jnp.arange(xcap)[None, :]) % ocap
                ).astype(jnp.int32)
        msgs = jnp.take_along_axis(st.og, cols[:, :, None], axis=1)
        mask = jnp.arange(xcap)[None, :] < k[:, None]
        msgs = jnp.where(mask[:, :, None], msgs, 0)
        st = st._replace(og_head=st.og_head + k)
        return st, msgs, k.astype(jnp.int32)

    # --- the superstep -------------------------------------------------------
    def superstep(st: ShardState, do_test, gstep):
        my_shard = (jax.lax.axis_index(axis_name).astype(jnp.int32)
                    if axis_name else jnp.int32(0))
        st = ingest(st, my_shard)
        st = process_main(st, my_shard)
        if relaxed:
            st = jax.lax.cond(do_test,
                              lambda s: process_test_q(s, my_shard),
                              lambda s: s, st)
        st, msgs, k = flush(st)
        if axis_name is not None and S > 1:
            msgs = jax.lax.all_to_all(msgs, axis_name, 0, 0)
            k = jax.lax.all_to_all(k[:, None], axis_name, 0, 0)[:, 0]
            st = st._replace(inbox=msgs, in_cnt=k)
        elif S == 1:
            st = st._replace(inbox=msgs, in_cnt=k)
        activity = ((st.mq_tail - st.mq_head) + (st.tq_tail - st.tq_head)
                    + (st.og_tail - st.og_head).sum()
                    + st.in_cnt.sum().astype(jnp.int32))
        err = st.err
        if axis_name is not None:
            activity = jax.lax.psum(activity, axis_name)
            err = jax.lax.psum(err, axis_name)
        # Per-superstep history, recorded on device (capacity-1 buffers —
        # i.e. history off — simply drop every write past index 0).
        st = st._replace(
            hist_act=st.hist_act.at[gstep].set(activity, mode="drop"),
            hist_sent=st.hist_sent.at[gstep].set(st.n_sent_remote,
                                                 mode="drop"))
        return st, activity, err

    return superstep


# ---------------------------------------------------------------------------
# Compile-cached driver builders (runtime layer, DESIGN.md §6)
# ---------------------------------------------------------------------------

def _state_specs():
    return ShardState(*[P(_AXIS)] * len(ShardState._fields))


def _build_step_fn(topo: GHSTopology, params: GHSParams,
                   mesh: Optional[Mesh]):
    """Legacy per-superstep dispatch: (state, do_test, gstep) ->
    (state, [activity, err]) — ONE fused scalar readback per superstep
    (the old driver's two blocking ``int()`` fetches, stacked).

    Deliberately NOT compile-cached: the seed driver rebuilt and re-jitted
    its superstep on every invocation, and this retained path is the
    before/after baseline for ``bench_superstep_loop.py`` — the runtime
    layer's compile cache is one of the things being measured."""
    step_core = make_superstep(topo, params, _AXIS if mesh is not None
                               else None)
    donate = runtime.donation(0)
    if mesh is None:
        def f(st, do_test, gstep):
            st, act, err = step_core(st, do_test, gstep)
            return st, jnp.stack([act, err])
        return jax.jit(f, donate_argnums=donate)

    def f(stacked, do_test, gstep):
        st = ShardState(*[a[0] for a in stacked])
        st, act, err = step_core(st, do_test, gstep)
        st = ShardState(*[a[None] for a in st])
        return st, jnp.stack([act, err])

    fn = compat.shard_map(
        f, mesh,
        in_specs=(_state_specs(), P(), P()),
        out_specs=(_state_specs(), P()),
    )
    return jax.jit(fn, donate_argnums=donate)


@functools.lru_cache(maxsize=32)
def _build_interval_fn(topo: GHSTopology, params: GHSParams,
                       mesh: Optional[Mesh]):
    """Device-resident superstep loop: (state, step0, silent0, n_steps) ->
    (state, [abs_steps, silent_streak, err]).

    Runs up to ``n_steps`` supersteps in one ``lax.while_loop`` dispatch,
    breaking early on an error flag or once the consecutive-silent-check
    streak reaches ``empty_iter_cnt_to_break`` (paper §3.6) — the host
    reads back one fused length-3 vector per interval.  The vector carries
    the ABSOLUTE superstep count (``step0 + steps_run``) so the next
    interval can be dispatched straight from the previous one's un-fetched
    device outputs — the hand-off the double-buffered driver needs
    (DESIGN.md §11)."""
    step_core = make_superstep(topo, params, _AXIS if mesh is not None
                               else None)
    check = max(params.check_frequency, 1)
    empty_needed = max(params.empty_iter_cnt_to_break, 1)

    def interval_core(st, step0, silent0, n_steps):
        def cond(c):
            _, i, silent, err = c
            return (i < n_steps) & (silent < empty_needed) & (err == 0)

        def body(c):
            st, i, silent, _ = c
            gstep = step0.astype(jnp.int32) + i
            do_test = (gstep % check) == (check - 1)
            st, act, err = step_core(st, do_test, gstep)
            silent = jnp.where(act == 0, silent + 1, jnp.int32(0))
            return st, i + 1, silent, err

        st, i, silent, err = jax.lax.while_loop(
            cond, body,
            (st, jnp.int32(0), silent0.astype(jnp.int32), jnp.int32(0)))
        return st, jnp.stack([step0.astype(jnp.int32) + i, silent, err])

    donate = runtime.donation(0)
    if mesh is None:
        return jax.jit(interval_core, donate_argnums=donate)

    def f(stacked, step0, silent0, n_steps):
        st = ShardState(*[a[0] for a in stacked])
        st, scal = interval_core(st, step0, silent0, n_steps)
        return ShardState(*[a[None] for a in st]), scal

    fn = compat.shard_map(
        f, mesh,
        in_specs=(_state_specs(), P(), P(), P()),
        out_specs=(_state_specs(), P()),
    )
    return jax.jit(fn, donate_argnums=donate)


# ---------------------------------------------------------------------------
# Drivers (both route through repro.core.runtime.interval_loop)
# ---------------------------------------------------------------------------

_ERR_DESCRIPTIONS = (
    (ERR_QUEUE_OVERFLOW,
     "ERR_QUEUE_OVERFLOW: a message ring exceeded its capacity — raise "
     "params.queue_capacity (or leave it 0 to auto-size from the shard "
     "adjacency)"),
    (ERR_HASH_MISS,
     "ERR_HASH_MISS: edge hash lookup failed (hash table too small — raise "
     "params.hash_table_factor)"),
    (ERR_LOGIC,
     "ERR_LOGIC: protocol invariant violated (engine bug)"),
)


def _raise_on_err(err: int):
    if err:
        what = "; ".join(d for flag, d in _ERR_DESCRIPTIONS if err & flag)
        raise RuntimeError(
            f"GHS engine error flags: {err:#x} ({what or 'unknown flag'})")


def _device_driver(state, topo, params, mesh, stats, total_cap: int):
    """Fused loop: ≤ 1 host sync per ``check_frequency`` supersteps.

    The superstep / silent-streak counters ride the interval fn's device
    scalar vector (absolute step counts), so the next interval is
    dispatched straight from the previous one's un-fetched outputs — which
    is what lets ``params.interval_pipeline`` double-buffer this driver
    (DESIGN.md §11).  A silent state is a while-loop fixed point (the cond
    fails immediately), so the speculative trailing interval cannot
    perturb the forest; an errored interval's successor wastes bounded
    device work whose results the raise discards."""
    fn = _build_interval_fn(topo, params, mesh)
    interval = max(params.check_frequency, 1)
    empty_needed = max(params.empty_iter_cnt_to_break, 1)
    overlap = (runtime.resolve_interval_pipeline(params.interval_pipeline)
               == 1)
    box = dict(steps=0, dispatched=0)

    def dispatch(s):
        st, scal = s
        # Clamp by the DISPATCHED total: under overlap this runs before
        # the previous interval's readback is consumed.  A clamped-to-zero
        # interval is a device no-op returning its inputs' counters.
        n_steps = max(min(interval, total_cap - box["dispatched"]), 0)
        box["dispatched"] += n_steps
        st, scal = fn(st, scal[0], scal[1], np.int32(n_steps))
        return (st, scal), scal

    def finish(s, vals):
        steps_abs, silent, err = (int(v) for v in np.asarray(vals))
        _raise_on_err(err)
        box["steps"] = steps_abs
        return s, silent >= empty_needed

    state, _ = runtime.interval_loop(
        (state, jnp.zeros((3,), jnp.int32)), dispatch, finish, stats=stats,
        max_intervals=-(-total_cap // interval),
        fail_msg=f"GHS engine did not reach silence in {total_cap} steps",
        overlap=overlap)
    return state, box["steps"]


def _host_driver(state, topo, params, mesh, stats, total_cap: int):
    """Legacy per-superstep loop (``round_loop="host"``), retained as the
    before/after baseline; its two scalar fetches per superstep are fused
    into one stacked transfer."""
    fn = _build_step_fn(topo, params, mesh)
    check = max(params.check_frequency, 1)
    empty_needed = max(params.empty_iter_cnt_to_break, 1)
    box = dict(steps=0, silent=0)

    def dispatch(st):
        step = box["steps"]
        do_test = bool(step % check == check - 1)
        return fn(st, do_test, np.int32(step))

    def finish(st, vals):
        act, err = (int(v) for v in np.asarray(vals))
        _raise_on_err(err)
        box["steps"] += 1
        box["silent"] = box["silent"] + 1 if act == 0 else 0
        return st, box["silent"] >= empty_needed

    state = runtime.interval_loop(
        state, dispatch, finish, stats=stats, max_intervals=total_cap,
        fail_msg=f"GHS engine did not reach silence in {total_cap} steps")
    return state, box["steps"]


def minimum_spanning_forest(
    graph,
    params: GHSParams = DEFAULT_PARAMS,
    mesh: Optional[Mesh] = None,
    max_supersteps: Optional[int] = None,
    collect_history: bool = False,
) -> tuple[ForestResult, GHSStats]:
    """Run the faithful GHS engine; returns forest + execution stats.

    ``graph`` is a host :class:`Graph` or a
    :class:`repro.core.pipeline.DeviceEdges` (mirrored to host once — this
    engine initializes its CSR shards host-side).  ``params.partitioner``
    picks the vertex distribution: non-block partitions are realized as a
    relabeling that preserves edge order and canonical ids
    (:func:`runtime.vertex_partitioned`), so the forest — recorded by
    canonical edge id — is bit-identical for every partitioner.

    ``params.round_loop`` selects the driver: ``"device"`` (default) runs
    ``check_frequency`` supersteps per host dispatch inside a fused
    ``lax.while_loop``; ``"host"`` is the legacy one-superstep-per-dispatch
    loop.  Both produce bit-identical forests.
    """
    graph = runtime.as_graph(graph)
    loop = runtime.resolve_round_loop(params.round_loop)
    S = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    n = graph.num_vertices
    cap = max_supersteps or (40 * n + 2000)
    empty_needed = max(params.empty_iter_cnt_to_break, 1)
    total_cap = cap + empty_needed - 1   # silence-confirmation steps are free
    topo, shards = init_shards(
        runtime.vertex_partitioned(graph, params.partitioner, S), S, params,
        history_capacity=total_cap if collect_history else 1)

    if mesh is not None:
        state = jax.device_put(stack_shards(shards),
                               NamedSharding(mesh, P(_AXIS)))
    else:
        state = jax.tree.map(jnp.asarray, shards[0])

    stats = GHSStats()
    driver = _device_driver if loop == "device" else _host_driver
    state, steps = driver(state, topo, params, mesh, stats, total_cap)
    stats.supersteps = steps

    # Final state fetch: forest + counters + histories, one transfer.
    state_h = jax.device_get(state)
    stats.host_syncs += 1          # final state fetch
    stats.extra_syncs += 1

    # Extract branch edges (union over shards & directions).
    se = np.asarray(state_h.se)
    ceid = np.asarray(state_h.ceid)
    if mesh is None:
        se, ceid = se[None], ceid[None]
    mask = np.zeros(graph.num_edges, dtype=bool)
    for s in range(se.shape[0]):
        sel = se[s] == BRANCH
        mask[ceid[s][sel]] = True
    res = runtime.forest_from_mask(graph, mask)

    bytes_per_msg = topo.lanes * 4
    stats.processed = int(np.sum(np.asarray(state_h.n_processed)))
    stats.productive = int(np.sum(np.asarray(state_h.n_productive)))
    stats.sent_remote = int(np.sum(np.asarray(state_h.n_sent_remote)))
    stats.sent_local = int(np.sum(np.asarray(state_h.n_sent_local)))
    stats.halted_fragments = int(np.sum(np.asarray(state_h.halted)))
    stats.bytes_remote = stats.sent_remote * bytes_per_msg
    if collect_history:
        hist_act = np.asarray(state_h.hist_act)
        hist_sent = np.asarray(state_h.hist_sent)
        if mesh is None:
            hist_act, hist_sent = hist_act[None], hist_sent[None]
        # activity is psum'd (identical on every shard); sends are per-shard
        # cumulative counts, summed here to the global cumulative series.
        stats.queue_history = tuple(
            int(x) for x in hist_act[0][:steps])
        stats.bytes_history = tuple(
            int(x) * bytes_per_msg for x in hist_sent.sum(axis=0)[:steps])
    return res, stats
