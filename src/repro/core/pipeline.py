"""Device-resident graph pipeline: sharded generation → §3.1 preprocessing →
engine hand-off without an edge round-trip through host memory (DESIGN.md §7).

PRs 1–2 made both MST engines device-resident, which left the host-side
numpy graph layer (Python-loop generators, ``np.lexsort`` dedup, host pad +
upload) dominating end-to-end wall clock.  This module moves the whole
build onto the accelerator:

* **Counter-based generation.**  Every sampler is a pure function of
  ``(seed, sample index)`` built on the splitmix64 finalizer, written ONCE
  against the array namespace (``numpy`` or ``jax.numpy``) — the same code
  runs as the host oracle and as the jitted device builder, so the two are
  *byte-identical* by construction, for any shard count (sample *i* never
  depends on its neighbors).  Weights are ``(bits23 + 0.5) · 2⁻²³`` — every
  float op is exact (or a single correctly-rounded IEEE op), so numpy and
  XLA agree bit-for-bit and the open-interval (0, 1) invariant holds
  without clipping.
* **On-device §3.1 preprocessing.**  Self-loop drop + multi-edge dedup
  keeping the min-weight copy: one stable ``lexsort`` over (pair-id,
  weight) — the same packed-key order :mod:`repro.core.keys` gives the
  engines — a first-occurrence mask, and a prefix-sum stream compaction
  into a fixed-capacity canonical edge buffer.  This mirrors
  :func:`repro.core.graph.preprocess` operation-for-operation (both sorts
  are stable, identical keys ⇒ identical permutation), which is what makes
  the device pipeline's output byte-identical to the numpy oracle.
* **Sharded hand-off.**  Under a mesh every shard runs the counter-based
  build redundantly and keeps its own slice of the canonical buffer
  (``shard_map``, ZERO collectives — redundant compute is wall-clock-free
  on parallel hardware, while MB-size gathers stall XLA:CPU rendezvous),
  so the outputs carry the engines' edge sharding and
  :func:`repro.core.runtime.prepare_edges` hands :class:`DeviceEdges`
  straight to the Borůvka engine — the only host transfer in the whole
  build is ONE scalar (the deduped edge count).

Generator kinds (§4 shapes + new scenarios):

* ``rmat``    — R-MAT recursive-quadrant sampling, Graph500 parameters,
  affine odd-multiplier vertex scrambling (hub dispersal).
* ``random``  — uniform G(n, m) endpoint sampling.
* ``geo_knn`` — 2D geometric locality: vertices on a √n-side lattice, each
  sample links a vertex to a uniform neighbor in a 5×5 window, weight
  dominated by squared Euclidean distance (approximate-kNN structure).
* ``grid``    — road-like: 4-neighbor lattice links with light weights
  plus a sparse set of heavy long-range shortcuts.
* ``chain``   — adversarial path (maximum Borůvka round count / fragment
  depth); half the samples are duplicate path edges to stress dedup.
* ``star``    — adversarial hub (every edge incident to vertex 0; each
  spoke sampled twice), the worst case for block partitioners and for the
  GHS wake-up fan-out.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np

from repro.core import keys as keys_lib
from repro.core.graph import Graph, PAD_VERTEX, preprocess

KINDS = ("rmat", "random", "geo_knn", "grid", "chain", "star")

# R-MAT quadrant thresholds (a=0.57, b=0.19, c=0.19 — Graph500).
_RMAT_T = (np.float32(0.57), np.float32(0.76), np.float32(0.95))
_GEO_WINDOW = 2                     # 5×5 neighbor window
_MASK64 = (1 << 64) - 1


@dataclasses.dataclass(frozen=True)
class GraphSpec:
    """Static description of one generated graph (hashable ⇒ jit-cacheable)."""

    kind: str
    scale: int                      # log2(num_vertices), paper convention
    avg_degree: int = 32
    seed: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown generator kind {self.kind!r}; options: {KINDS}")
        if not 1 <= self.scale <= 26:
            # scale 0 has no valid chain/star edge; > 26 overflows the
            # narrow-key/pid packings and any realistic sample buffer.
            raise ValueError(f"scale must be in [1, 26], got {self.scale}")

    @property
    def num_vertices(self) -> int:
        return 1 << self.scale

    @property
    def num_samples(self) -> int:
        """Raw (possibly loop/multi-edge) samples drawn, before §3.1."""
        n = self.num_vertices
        if self.kind in ("rmat", "random", "geo_knn"):
            return n * self.avg_degree // 2
        if self.kind == "grid":
            return 2 * n + max(n // 16, 1)      # lattice links + shortcuts
        return 2 * max(n - 1, 1)                # chain / star: spokes twice


# ---------------------------------------------------------------------------
# Counter-based RNG — shared numpy / jax.numpy implementation
# ---------------------------------------------------------------------------

def _stream_base(seed: int, stream: int) -> np.uint64:
    """Per-(seed, stream) xor constant, computed in exact Python ints."""
    return np.uint64(
        ((seed * 0x9E3779B97F4A7C15) ^ (stream * 0xD6E8FEB86659FD93)
         ^ 0xA5A5A5A55A5A5A5A) & _MASK64)


def _rand_u64(seed: int, stream: int, ctr):
    """splitmix64 (keys.py finalizer) over a uint64 counter array."""
    return keys_lib.splitmix64(ctr ^ _stream_base(seed, stream))


def _to_f32_unit(bits23):
    """Exact (0, 1) float32 from 23 random bits: every op IEEE-exact or a
    single correctly-rounded op — numpy and XLA agree bit-for-bit."""
    return ((bits23.astype(np.float32) + np.float32(0.5))
            * np.float32(2.0 ** -23))


def _unif01(seed: int, stream: int, ctr):
    return _to_f32_unit((_rand_u64(seed, stream, ctr)
                         >> np.uint64(41)).astype(np.uint32))


# ---------------------------------------------------------------------------
# Samplers — pure (seed, counter) → (src u64, dst u64, weight f32)
# ---------------------------------------------------------------------------
# Invalid samples are emitted as self-loops; §3.1 preprocessing drops them.

def _sample_rmat(xp, spec: GraphSpec, ctr):
    n, seed = spec.num_vertices, spec.seed
    src = xp.zeros(ctr.shape, np.uint64)
    dst = xp.zeros(ctr.shape, np.uint64)
    for lvl in range(spec.scale):
        r = _unif01(seed, lvl, ctr)
        q = ((r >= _RMAT_T[0]).astype(np.uint64)
             + (r >= _RMAT_T[1]).astype(np.uint64)
             + (r >= _RMAT_T[2]).astype(np.uint64))
        src = (src << np.uint64(1)) | (q >> np.uint64(1))
        dst = (dst << np.uint64(1)) | (q & np.uint64(1))
    # Affine odd-multiplier scramble mod n (power of two): disperses the
    # low-id hubs across any block distribution, like Graph500's permutation.
    one = np.asarray([1], np.uint64)        # array, not scalar: silent wrap
    a = int(_rand_u64(seed, 97, one)[0]) | 1
    b = int(_rand_u64(seed, 98, one)[0])
    mul, add, mask = (np.uint64(a & _MASK64), np.uint64(b & _MASK64),
                      np.uint64(n - 1))
    src = (src * mul + add) & mask
    dst = (dst * mul + add) & mask
    return src, dst, _unif01(seed, 64, ctr)


def _sample_random(xp, spec: GraphSpec, ctr):
    n, seed = spec.num_vertices, spec.seed
    mask = np.uint64(n - 1)
    src = _rand_u64(seed, 0, ctr) & mask
    dst = _rand_u64(seed, 1, ctr) & mask
    return src, dst, _unif01(seed, 2, ctr)


def _sample_geo_knn(xp, spec: GraphSpec, ctr):
    n, seed = spec.num_vertices, spec.seed
    side = 1 << (spec.scale // 2)
    rows = n // side
    W = _GEO_WINDOW
    u = _rand_u64(seed, 0, ctr) & np.uint64(n - 1)
    dx = (_rand_u64(seed, 1, ctr) % np.uint64(2 * W + 1)).astype(np.int64) - W
    dy = (_rand_u64(seed, 2, ctr) % np.uint64(2 * W + 1)).astype(np.int64) - W
    vx = (u % np.uint64(side)).astype(np.int64)
    vy = (u // np.uint64(side)).astype(np.int64)
    nx = xp.clip(vx + dx, 0, side - 1)
    ny = xp.clip(vy + dy, 0, rows - 1)
    v = (ny * side + nx).astype(np.uint64)
    dist2 = ((nx - vx) ** 2 + (ny - vy) ** 2).astype(np.uint64)   # ≤ 2W²
    # weight bits: distance-dominant high lane, hash jitter low lane — stays
    # ≤ 2²³ so the int→f32 conversion is exact.
    wbits = ((dist2 << np.uint64(19))
             | (_rand_u64(seed, 3, ctr) & np.uint64((1 << 19) - 1)))
    return u, v, _to_f32_unit(wbits.astype(np.uint32))


def _sample_grid(xp, spec: GraphSpec, ctr):
    n, seed = spec.num_vertices, spec.seed
    side = 1 << (spec.scale // 2)
    rows = n // side
    is_right = ctr < np.uint64(n)
    is_down = (ctr >= np.uint64(n)) & (ctr < np.uint64(2 * n))
    lattice = is_right | is_down
    v = ctr & np.uint64(n - 1)
    vx = v % np.uint64(side)
    vy = v // np.uint64(side)
    # Border links clamp to self-loops (dropped): a road grid, not a torus.
    right = xp.where(vx < np.uint64(side - 1), v + np.uint64(1), v)
    down = xp.where(vy < np.uint64(rows - 1), v + np.uint64(side), v)
    su = _rand_u64(seed, 10, ctr) & np.uint64(n - 1)
    sv = _rand_u64(seed, 11, ctr) & np.uint64(n - 1)
    src = xp.where(lattice, v, su)
    dst = xp.where(is_right, right, xp.where(is_down, down, sv))
    # Lattice roads are light (< 0.5); shortcuts are heavy (≥ 0.5) highways.
    bits22 = _rand_u64(seed, 12, ctr) & np.uint64((1 << 22) - 1)
    wbits = xp.where(lattice, bits22, bits22 | np.uint64(1 << 22))
    return src, dst, _to_f32_unit(wbits.astype(np.uint32))


def _sample_chain(xp, spec: GraphSpec, ctr):
    n, seed = spec.num_vertices, spec.seed
    links = max(n - 1, 1)
    j = xp.where(ctr < np.uint64(links), ctr,
                 _rand_u64(seed, 5, ctr) % np.uint64(links))
    return j, j + np.uint64(1), _unif01(seed, 6, ctr)


def _sample_star(xp, spec: GraphSpec, ctr):
    n, seed = spec.num_vertices, spec.seed
    spoke = (ctr % np.uint64(max(n - 1, 1))) + np.uint64(1)
    return xp.zeros(ctr.shape, np.uint64), spoke, _unif01(seed, 7, ctr)


_SAMPLERS = {
    "rmat": _sample_rmat,
    "random": _sample_random,
    "geo_knn": _sample_geo_knn,
    "grid": _sample_grid,
    "chain": _sample_chain,
    "star": _sample_star,
}


def raw_samples(spec: GraphSpec, xp=np, ctr=None):
    """Raw (src, dst, weight) samples under ``xp`` ∈ {numpy, jax.numpy}."""
    if ctr is None:
        ctr = xp.arange(spec.num_samples, dtype=np.uint64)
    return _SAMPLERS[spec.kind](xp, spec, ctr)


# ---------------------------------------------------------------------------
# Edge sampling (DESIGN.md §10) — the Filter-Borůvka counter-based sampler
# ---------------------------------------------------------------------------
# A sample decision is a pure function of (seed, canonical edge id) built on
# the same splitmix64 finalizer as the generators, written once against the
# array namespace — so the numpy oracle and any jitted/sharded evaluation are
# byte-identical, and the decision for edge i never depends on which shard
# holds it (determinism at ANY shard count, the §10 contract).

_SAMPLE_STREAM = 0x5A17                 # disjoint from generator streams

def sample_mask(seed: int, rate: float, eid):
    """Bernoulli(rate) keep-mask over canonical edge ids (numpy or jnp).

    ``eid`` is a uint64 array of canonical edge ids; ``rate`` is a host
    float.  Endpoints are exact: rate ≤ 0 keeps nothing (the empty-sample
    path the filter driver must survive), rate ≥ 1 keeps everything.
    """
    eid = eid.astype(np.uint64)
    if rate <= 0.0:
        return eid != eid
    if rate >= 1.0:
        return eid == eid
    thresh = np.uint64(int(rate * 2.0 ** 64))
    return _rand_u64(seed, _SAMPLE_STREAM, eid) < thresh


def sample_mask_fixed_k(xp, seed: int, k: int, eid):
    """Fixed-size variant: keep exactly the ``k`` smallest splitmix64 draws.

    The k-th draw is a GLOBAL order statistic, so this must be evaluated
    over the full edge-id range to stay shard-count invariant (the driver
    defaults to the Bernoulli form, which needs no global pass)."""
    eid = eid.astype(np.uint64)
    if k <= 0:
        return eid != eid
    if k >= int(eid.shape[0]):
        return eid == eid
    h = _rand_u64(seed, _SAMPLE_STREAM, eid)
    kth = xp.sort(h)[k - 1]
    return h <= kth                     # draws are distinct w.h.p.; ties only
                                        # ever widen the sample, never drop it


def sample_device_edges(edges: "DeviceEdges", rate: float, seed: int = 0):
    """Device-resident Bernoulli sample over a :class:`DeviceEdges` buffer.

    Returns a (capacity,) bool device array carrying the edge sharding of
    ``edges`` — the decision reads each slot's canonical edge id from the
    key's low lane, so it is invariant to how slots are distributed.
    Padding slots (INF keys) are never sampled.
    """
    from jax.experimental import enable_x64
    with enable_x64():
        eid = edges.key & np.uint64(0xFFFFFFFF)
        return sample_mask(seed, rate, eid) & (edges.key != keys_lib.INF_KEY)


# ---------------------------------------------------------------------------
# Host oracle
# ---------------------------------------------------------------------------

def build_host(spec: GraphSpec) -> Graph:
    """The numpy path: same samplers, :func:`graph.preprocess` for §3.1.

    This is the oracle the device pipeline is held byte-identical to."""
    src, dst, w = raw_samples(spec, np)
    return preprocess(src, dst, w, spec.num_vertices)


# ---------------------------------------------------------------------------
# Device pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeviceEdges:
    """Canonical (preprocessed) edge set resident on device.

    ``src``/``dst``/``key`` have static capacity ``cap`` (a power-of-two
    multiple of the shard count); slots ≥ ``num_edges`` hold the inert
    padding sentinels (``PAD_VERTEX`` endpoints, ``INF_KEY``).  Edge *i* is
    canonical edge *i* of the byte-identical host graph: keys carry
    (weight-bits ‖ edge-id) exactly as :meth:`Graph.packed_keys` would.
    """

    num_vertices: int
    num_edges: int
    src: object                 # (cap,) int32 device array
    dst: object                 # (cap,) int32
    key: object                 # (cap,) uint64
    mesh: object = None
    spec: Optional[GraphSpec] = None

    @property
    def capacity(self) -> int:
        return int(self.src.shape[0])

    @functools.cached_property
    def _host_graph(self) -> Graph:
        import jax
        m = self.num_edges
        src, dst, key = jax.device_get((self.src, self.dst, self.key))
        return Graph(
            num_vertices=self.num_vertices,
            src=np.asarray(src)[:m].astype(np.int32),
            dst=np.asarray(dst)[:m].astype(np.int32),
            weight=keys_lib.unpack_weight_np(np.asarray(key)[:m]),
        )

    def to_graph(self) -> Graph:
        """Host mirror (one device→host fetch, cached) — for oracles,
        reporting, and engines that initialize on host (faithful GHS)."""
        return self._host_graph


# ---------------------------------------------------------------------------
# Batched packing (DESIGN.md §8) — many graphs per engine dispatch
# ---------------------------------------------------------------------------

BATCH_BUCKETS = ("pow2", "exact")


@dataclasses.dataclass
class GraphBatch:
    """One shape bucket of a packed multi-graph batch.

    All lanes share the padded shape ``(n_pad, cap)``: lane *r* holds graph
    ``graphs[r]`` (position ``indices[r]`` of the original sequence) with
    its canonical edges in slots ``[0, num_edges[r])`` and the inert padding
    sentinels behind them (``PAD_VERTEX`` endpoints, ``INF_KEY`` keys — the
    same invariants as single-graph padding, see :mod:`repro.core.graph`).
    Vertices ``[num_vertices[r], n_pad)`` are padding too: they own no edges,
    so they stay isolated fragments and never touch the forest.  ``slot`` is
    the per-lane slot side-lane (:func:`repro.core.partition.batched_slots`).
    """

    indices: tuple                  # positions in the caller's sequence
    graphs: tuple                   # the bucket's Graph objects, lane order
    n_pad: int
    cap: int
    num_vertices: np.ndarray        # (B,) int64
    num_edges: np.ndarray           # (B,) int64
    src: np.ndarray                 # (B, cap) int32
    dst: np.ndarray                 # (B, cap) int32
    key: np.ndarray                 # (B, cap) uint64
    slot: np.ndarray                # (B, cap) int32

    @property
    def batch_size(self) -> int:
        return len(self.indices)

    def unpack(self, mask_batch) -> list:
        """Per-lane :class:`~repro.core.kruskal_ref.ForestResult` list from
        a (B, cap) winner bitmap — ONE blocking device→host transfer for
        the whole bucket, however many graphs ride it."""
        import jax
        from repro.core import partition as partition_lib
        from repro.core import runtime as runtime_lib
        masks = np.asarray(jax.device_get(mask_batch), dtype=bool)
        out = []
        for r, g in enumerate(self.graphs):
            m = int(self.num_edges[r])
            layout = partition_lib.identity_layout(m, self.cap)
            canon = layout.canonical_mask(masks[r], m)
            res = runtime_lib.forest_from_mask(g, canon)
            res.check_consistent(g.num_vertices)
            out.append(res)
        return out


def _bucket_shape(n: int, m: int, bucket: str) -> Tuple[int, int]:
    """Padded (n_pad, cap) for one graph under a bucketing policy.

    Degenerate shapes are well-defined: an edgeless graph gets ``cap=1``
    under ``"exact"`` (one all-sentinel lane slot) but ``cap=8`` under
    ``"pow2"`` (the shared-executable floor) — both lanes solve and unpack
    to an empty forest; see the degenerate-corpus tests."""
    from repro.core.partition import pow2ceil
    if bucket == "pow2":
        return pow2ceil(max(n, 1)), pow2ceil(max(m, 8))
    return max(n, 1), max(m, 1)


def bucket_shape(
    num_vertices: int,
    num_edges: int,
    *,
    bucket: str = "pow2",
    max_vertices: Optional[int] = None,
    max_edges: Optional[int] = None,
) -> Tuple[int, int]:
    """Admission key for one graph: the padded ``(n_pad, cap)`` it would be
    packed under by :func:`pack_batch`.

    This is the incremental half of the batching contract — a serving loop
    calls it per request to route the graph into a per-shape queue without
    re-listing (or re-bucketing) everything already queued, then hands each
    queue to :func:`pack_bucket` at flush time.  Raises the same
    ``ValueError``s as :func:`pack_batch` for an unknown policy or a graph
    exceeding ``max_vertices`` / ``max_edges`` (the backpressure signal).
    """
    if bucket not in BATCH_BUCKETS:
        raise ValueError(
            f"unknown batch bucket policy {bucket!r}; options: "
            f"{BATCH_BUCKETS}")
    n, m = int(num_vertices), int(num_edges)
    if max_vertices is not None and n > max_vertices:
        raise ValueError(
            f"graph exceeds pack_batch capacity: num_vertices={n} "
            f"> max_vertices={max_vertices}")
    if max_edges is not None and m > max_edges:
        raise ValueError(
            f"graph exceeds pack_batch capacity: num_edges={m} "
            f"> max_edges={max_edges}")
    return _bucket_shape(n, m, bucket)


def pack_bucket(graphs, n_pad: int, cap: int, *,
                indices: Optional[tuple] = None) -> GraphBatch:
    """Pack an already-admitted queue of same-bucket graphs into one
    :class:`GraphBatch` — the flush half of incremental admission.

    Every graph must satisfy ``num_vertices <= n_pad`` and
    ``num_edges <= cap`` (i.e. have been routed here by
    :func:`bucket_shape`); violations raise ``ValueError``.  ``indices``
    optionally records the caller's request ordering (defaults to
    ``0..B-1``)."""
    from repro.core import partition as partition_lib

    graph_list = list(graphs)
    if not graph_list:
        raise ValueError("pack_bucket needs at least one graph")
    idxs = tuple(range(len(graph_list))) if indices is None \
        else tuple(indices)
    if len(idxs) != len(graph_list):
        raise ValueError(
            f"indices length {len(idxs)} != batch size {len(graph_list)}")
    bsz = len(graph_list)
    src = np.full((bsz, cap), PAD_VERTEX, np.int32)
    dst = np.full((bsz, cap), PAD_VERTEX, np.int32)
    key = np.full((bsz, cap), keys_lib.INF_KEY, np.uint64)
    for r, g in enumerate(graph_list):
        n, m = g.num_vertices, g.num_edges
        if n > n_pad or m > cap:
            raise ValueError(
                f"lane {r} does not fit bucket ({n_pad}, {cap}): "
                f"num_vertices={n}, num_edges={m}")
        src[r, :m] = g.src
        dst[r, :m] = g.dst
        key[r, :m] = g.packed_keys
    return GraphBatch(
        indices=idxs,
        graphs=tuple(graph_list),
        n_pad=int(n_pad), cap=int(cap),
        num_vertices=np.array(
            [g.num_vertices for g in graph_list], np.int64),
        num_edges=np.array([g.num_edges for g in graph_list], np.int64),
        src=src, dst=dst, key=key,
        slot=partition_lib.batched_slots(bsz, cap))


def pack_batch(
    graphs,
    *,
    bucket: str = "pow2",
    max_vertices: Optional[int] = None,
    max_edges: Optional[int] = None,
) -> list:
    """Bucket ``graphs`` by padded shape and pack each bucket into
    leading-axis-stacked arrays ready for the vmapped engine.

    ``bucket`` — ``"pow2"`` (default) rounds each graph's (n, m) up to
    powers of two so mixed sizes share executables; ``"exact"`` buckets
    only graphs with identical (n, m) together (no per-graph padding, one
    executable per distinct shape).  Graphs never share a bucket unless
    their padded shapes match exactly, so no lane is ever solved at the
    wrong rank.

    ``max_vertices`` / ``max_edges`` bound the padded lane shape; a graph
    exceeding either capacity raises ``ValueError`` (the serving-path
    guard: an oversized query must be rejected, not silently truncated).
    """
    if bucket not in BATCH_BUCKETS:
        raise ValueError(
            f"unknown batch bucket policy {bucket!r}; options: "
            f"{BATCH_BUCKETS}")
    graph_list = list(graphs)
    buckets: dict = {}
    for i, g in enumerate(graph_list):
        n, m = g.num_vertices, g.num_edges
        if max_vertices is not None and n > max_vertices:
            raise ValueError(
                f"graph {i} exceeds pack_batch capacity: num_vertices={n} "
                f"> max_vertices={max_vertices}")
        if max_edges is not None and m > max_edges:
            raise ValueError(
                f"graph {i} exceeds pack_batch capacity: num_edges={m} "
                f"> max_edges={max_edges}")
        buckets.setdefault(_bucket_shape(n, m, bucket), []).append(i)

    return [
        pack_bucket([graph_list[i] for i in idxs], n_pad, cap,
                    indices=tuple(idxs))
        for (n_pad, cap), idxs in sorted(buckets.items())
    ]


def _capacity(spec: GraphSpec, num_shards: int) -> int:
    """Power-of-two capacity ≥ num_samples, divisible by the shard count."""
    from repro.core.partition import pow2ceil
    return pow2ceil(-(-max(spec.num_samples, 8) // num_shards)) * num_shards


def _preprocess_device(src, dst, w, ctr, *,
                       num_samples: int, cap: int, scale: int):
    """§3.1 on device, byte-identical to :func:`graph.preprocess`.

    The numpy oracle lexsorts by (pair-id, weight) and keeps each pair's
    first copy.  Padding lanes (counter ≥ num_samples) and self-loops ride
    to the tail under an all-ones key and are dropped.

    **Narrow-key fast path** (``2·scale + 30 ≤ 64``, i.e. scale ≤ 17): a
    weight in (0, 1) has zero sign and exponent-MSB bits, so its IEEE-754
    pattern fits 30 bits and the whole (u, v, weight-bits) triple packs
    into ONE uint64 — a *key-only* sort (no payload movement, ~6x cheaper
    in XLA:CPU than a payload-carrying sort) orders pairs exactly like the
    oracle's (pair-id, weight) lexsort, and every field unpacks from the
    sorted key itself.  Each group's first lane IS its min-weight copy.

    **General path** (scale > 17): 64-bit pair-id sort carrying the weight
    as payload; the min-weight copy is recovered with a segmented
    scatter-min (among equal (pair-id, weight) lanes all payloads are
    identical, so the missing secondary sort cannot change the bytes).
    """
    import jax
    import jax.numpy as jnp
    u = jnp.minimum(src, dst)
    v = jnp.maximum(src, dst)
    drop = (u == v) | (ctr >= np.uint64(num_samples))
    slots = jnp.arange(cap, dtype=jnp.int32)

    if 2 * scale + 30 <= 64:
        wbits = w.view(jnp.uint32).astype(jnp.uint64)   # < 2**30 for (0,1)
        key = jnp.where(
            drop, keys_lib.INF_KEY,
            (u << np.uint64(scale + 30)) | (v << np.uint64(30)) | wbits)
        (key_s,) = jax.lax.sort((key,), num_keys=1)
        pid_s = key_s >> np.uint64(30)                  # (u ‖ v) lanes
        valid = key_s != keys_lib.INF_KEY
        first = valid & jnp.concatenate(
            [jnp.ones((1,), bool), pid_s[1:] != pid_s[:-1]])
        count = first.sum(dtype=jnp.int32)
        pos = jnp.cumsum(first.astype(jnp.int32)) - 1
        idx = jnp.where(first, pos, cap)
        vmask = np.uint64((1 << scale) - 1)
        u_s = (pid_s >> np.uint64(scale)).astype(jnp.int32)
        v_s = (pid_s & vmask).astype(jnp.int32)
        wb_s = (key_s & np.uint64((1 << 30) - 1)).astype(jnp.uint32)
        out_src = jnp.full((cap,), PAD_VERTEX, jnp.int32).at[idx].set(
            u_s, mode="drop")
        out_dst = jnp.full((cap,), PAD_VERTEX, jnp.int32).at[idx].set(
            v_s, mode="drop")
        out_wb = jnp.zeros((cap,), jnp.uint32).at[idx].set(wb_s, mode="drop")
        out_key = jnp.where(
            slots < count,
            (out_wb.astype(jnp.uint64) << np.uint64(32))
            | slots.astype(jnp.uint64),
            keys_lib.INF_KEY)
        return out_src, out_dst, out_key, count

    pid = jnp.where(drop, keys_lib.INF_KEY, (u << np.uint64(32)) | v)
    pid_s, w_s = jax.lax.sort((pid, w), num_keys=1)
    valid = pid_s != keys_lib.INF_KEY
    first = valid & jnp.concatenate(
        [jnp.ones((1,), bool), pid_s[1:] != pid_s[:-1]])
    count = first.sum(dtype=jnp.int32)
    # pos: canonical edge id of each lane's pair group (groups are pid-sorted
    # runs, so group rank == final edge index, as in the oracle).
    pos = jnp.cumsum(first.astype(jnp.int32)) - 1
    minw = jnp.full((cap,), np.float32(np.inf), jnp.float32).at[
        jnp.where(valid, pos, cap)].min(w_s, mode="drop")
    idx = jnp.where(first, pos, cap)        # one representative per group
    out_src = jnp.full((cap,), PAD_VERTEX, jnp.int32).at[idx].set(
        (pid_s >> np.uint64(32)).astype(jnp.int32), mode="drop")
    out_dst = jnp.full((cap,), PAD_VERTEX, jnp.int32).at[idx].set(
        (pid_s & np.uint64(0xFFFFFFFF)).astype(jnp.int32), mode="drop")
    out_key = jnp.where(slots < count,
                        keys_lib.pack_keys(minw, slots),
                        keys_lib.INF_KEY)
    return out_src, out_dst, out_key, count


@functools.lru_cache(maxsize=64)
def _build_fn(spec: GraphSpec, cap: int, mesh):
    """Jitted generate→preprocess for one (spec, capacity, mesh).

    Under a mesh the build is **communication-free**: every shard runs the
    counter-based build over the full sample range and keeps only its own
    slice of the canonical buffer (`shard_map`, zero collectives).  The
    samplers are pure per-counter functions, so the redundancy costs no
    wall clock on real parallel hardware (each chip does the same work the
    single-device build would), while any gather/partitioned-sort strategy
    pays MB-size collectives that XLA:CPU serializes through rendezvous
    stalls — measured orders of magnitude slower at these sizes.  A true
    distributed sample-sort is future work; byte-identity is unaffected
    either way (the sliced result IS the single-device result).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat

    def build(ctr):
        src, dst, w = _SAMPLERS[spec.kind](jnp, spec, ctr)
        return _preprocess_device(src, dst, w, ctr,
                                  num_samples=spec.num_samples, cap=cap,
                                  scale=spec.scale)

    if mesh is None:
        return jax.jit(build)

    num_shards = int(np.prod(mesh.devices.shape))
    block = cap // num_shards

    def build_shard(ctr):
        s, d, k, cnt = build(ctr)
        i = jax.lax.axis_index("x") * block
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i, block)
        return sl(s), sl(d), sl(k), cnt

    fn = compat.shard_map(
        build_shard, mesh,
        in_specs=(P(),), out_specs=(P("x"), P("x"), P("x"), P()))
    return jax.jit(fn)


def build(spec: GraphSpec, mesh=None) -> DeviceEdges:
    """Generate + preprocess ``spec`` entirely on device.

    Returns canonical edges in engine layout (sharded along ``"x"`` when a
    mesh is given).  The only blocking transfer is the deduped edge count —
    one scalar, metered by the caller's benchmark harness, not an edge
    round-trip.  For the same spec the result is byte-identical to
    :func:`build_host` at any shard count.
    """
    import jax
    from jax.experimental import enable_x64
    from jax.sharding import NamedSharding, PartitionSpec as P

    num_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    cap = _capacity(spec, num_shards)
    with enable_x64():
        ctr = np.arange(cap, dtype=np.uint64)
        if mesh is not None:
            ctr = jax.device_put(ctr, NamedSharding(mesh, P()))
        src, dst, key, count = _build_fn(spec, cap, mesh)(ctr)
        num_edges = int(count)              # the build's single host sync
    return DeviceEdges(num_vertices=spec.num_vertices, num_edges=num_edges,
                       src=src, dst=dst, key=key, mesh=mesh, spec=spec)
