"""The paper's primary contribution: distributed GHS/Boruvka MST in JAX.

Two engines share one total order over edges (packed weight+id keys), so
their outputs are bit-identical and oracle-checkable:

  * :mod:`repro.core.ghs_message` — faithful message-driven GHS
    (paper §2-3: queues, levels, relaxed Test ordering, hashing,
    message compression, aggregated exchange, silence termination).
  * :mod:`repro.core.boruvka_dist` — TPU-native synchronous engine
    (segment-min + hooking/pointer-doubling; beyond-paper).
"""
from repro.core.graph import Graph, build_csr, preprocess
from repro.core.generators import GENERATORS, generate
from repro.core.kruskal_ref import ForestResult, boruvka_numpy, kruskal
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import DEFAULT_PARAMS, GHSParams
from repro.core.partition import PARTITIONERS, get_partitioner
from repro.core.pipeline import DeviceEdges, GraphSpec, build, build_host

__all__ = [
    "Graph", "build_csr", "preprocess", "GENERATORS", "generate",
    "ForestResult", "boruvka_numpy", "kruskal", "minimum_spanning_forest",
    "DEFAULT_PARAMS", "GHSParams",
    "PARTITIONERS", "get_partitioner",
    "DeviceEdges", "GraphSpec", "build", "build_host",
]
