"""jit'd wrapper for the edge_hash kernel (build on host, probe on device)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.ghs_state import _build_hash_table
from repro.kernels.edge_hash import ref
from repro.kernels.edge_hash.edge_hash import hash_lookup


def build_table(lv: np.ndarray, u: np.ndarray, pos: np.ndarray, tsize: int):
    """Host-side vectorized linear-probe insertion (init-time, paper §3.3)."""
    return _build_hash_table(lv.astype(np.int32), u.astype(np.int32),
                             pos.astype(np.int32), tsize)


def lookup(table, q_lv, q_u, *, use_pallas: bool = True,
           interpret: bool = True):
    h_lv, h_u, h_pos = (jnp.asarray(t) for t in table)
    q_lv = jnp.asarray(q_lv, jnp.int32)
    q_u = jnp.asarray(q_u, jnp.int32)
    if use_pallas:
        return hash_lookup(h_lv, h_u, h_pos, q_lv, q_u, interpret=interpret)
    return ref.hash_lookup(h_lv, h_u, h_pos, q_lv, q_u)


def resolve_batch(h_lv, h_u, h_pos, q_lv, q_u, valid, *,
                  max_probes: int = 64):
    """Trace-safe batched (receiver, sender) → CSR-position pre-pass.

    Used inside the faithful GHS engine's superstep: resolves every valid
    incoming-message lane against the shard's edge hash table in one
    vectorized early-exit probe sweep.  Invalid lanes and lanes still
    unresolved after ``max_probes`` rounds return -1 — the dispatch loop
    falls back to the scalar probe for those, so the pre-pass can never
    change results, only skip work.
    """
    return ref.probe(h_lv, h_u, h_pos,
                     q_lv.astype(jnp.int32), q_u.astype(jnp.int32),
                     done0=~valid, max_probes=max_probes)
