"""Pure-jnp oracle for the edge_hash lookup kernel.

The probe core is shared with the batched inbox resolver
(:func:`repro.kernels.edge_hash.ops.resolve_batch`) that the faithful GHS
engine uses to edge-resolve a whole superstep's incoming messages in one
vectorized sweep.  Unlike the Pallas kernel's fixed-trip ``fori_loop``, the
core early-exits once every lane has frozen (hit or empty slot), so a
near-empty inbox costs only the one-or-two probe rounds it actually needs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ghs_state import HASH_K1, HASH_K2


def probe(h_lv, h_u, h_pos, q_lv, q_u, *, done0=None, max_probes: int = 64):
    """Linear-probe all query lanes in lock-step; -1 where unresolved.

    ``done0`` marks lanes that should not probe at all (e.g. invalid inbox
    slots); they return -1.  Lanes freeze on hit or empty slot; the loop
    exits as soon as every lane is frozen, or after ``max_probes`` rounds
    (callers treat a still-unresolved lane as "fall back to the scalar
    probe", never as a miss).
    """
    tsize = h_lv.shape[0]
    mixed = (q_lv.astype(jnp.uint32) * HASH_K1) ^ (q_u.astype(jnp.uint32)
                                                   * HASH_K2)
    idx = (mixed % np.uint32(tsize)).astype(jnp.int32)
    if done0 is None:
        done0 = jnp.zeros(q_lv.shape, jnp.bool_)

    def cond(carry):
        _, done, _, steps = carry
        return jnp.any(~done) & (steps < max_probes)

    def body(carry):
        idx, done, pos, steps = carry
        hit = (h_lv[idx] == q_lv) & (h_u[idx] == q_u)
        empty = h_pos[idx] < 0
        pos = jnp.where(~done & hit, h_pos[idx], pos)
        done = done | hit | empty
        idx = jnp.where(done, idx, (idx + 1) % np.int32(tsize))
        return idx, done, pos, steps + 1

    _, _, pos, _ = jax.lax.while_loop(
        cond, body,
        (idx, done0, jnp.full(q_lv.shape, -1, jnp.int32), jnp.int32(0)))
    return pos


def hash_lookup(h_lv, h_u, h_pos, q_lv, q_u, max_probes: int = 64):
    return probe(h_lv, h_u, h_pos, q_lv, q_u, max_probes=max_probes)
