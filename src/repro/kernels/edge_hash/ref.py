"""Pure-jnp oracle for the edge_hash lookup kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ghs_state import HASH_K1, HASH_K2


def hash_lookup(h_lv, h_u, h_pos, q_lv, q_u, max_probes: int = 64):
    tsize = h_lv.shape[0]
    mixed = (q_lv.astype(jnp.uint32) * HASH_K1) ^ (q_u.astype(jnp.uint32)
                                                   * HASH_K2)
    idx = (mixed % np.uint32(tsize)).astype(jnp.int32)

    def probe(_, carry):
        idx, done, pos = carry
        hit = (h_lv[idx] == q_lv) & (h_u[idx] == q_u)
        empty = h_pos[idx] < 0
        pos = jnp.where(~done & hit, h_pos[idx], pos)
        done = done | hit | empty
        idx = jnp.where(done, idx, (idx + 1) % np.int32(tsize))
        return idx, done, pos

    _, _, pos = jax.lax.fori_loop(
        0, max_probes, probe,
        (idx, jnp.zeros(q_lv.shape, jnp.bool_),
         jnp.full(q_lv.shape, -1, jnp.int32)))
    return pos
