"""Pallas TPU kernel: batched linear-probe hash lookup (paper §3.3 / C2).

The paper replaces linear edge search with an open-addressing hash keyed on
the (receiver, sender) vertex pair — an 18% node-time win.  This kernel is
the batched TPU version: a block of queries probes the table in lock-step,
each probe being one vectorized gather + compare on the VPU; queries that
hit (or reach an empty slot) freeze while the rest continue.

VMEM residency: tables are sharded with vertices, so the per-core slice at
pod scale (~2^24 edges / 256 chips × load factor ≈ 4.2 × 12 B ≈ 3.3 MB)
fits VMEM — the whole table is one BlockSpec block; queries stream through
the grid.  Hash mixing matches :func:`repro.core.ghs_state.hash_slot` (the
32-bit adaptation of the paper's ``((u << 32) | v) mod size``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.ghs_state import HASH_K1, HASH_K2

MAX_PROBES = 64


def _lookup_kernel(hlv_ref, hu_ref, hpos_ref, qlv_ref, qu_ref, out_ref,
                   *, tsize, max_probes):
    qlv = qlv_ref[...]
    qu = qu_ref[...]
    mixed = (qlv.astype(jnp.uint32) * HASH_K1) ^ (qu.astype(jnp.uint32)
                                                  * HASH_K2)
    idx = (mixed % np.uint32(tsize)).astype(jnp.int32)

    def probe(_, carry):
        idx, done, pos = carry
        klv = hlv_ref[idx]          # vectorized VMEM gather
        ku = hu_ref[idx]
        kpos = hpos_ref[idx]
        hit = (klv == qlv) & (ku == qu)
        empty = kpos < 0
        pos = jnp.where(~done & hit, kpos, pos)
        done = done | hit | empty
        idx = jnp.where(done, idx, (idx + 1) % np.int32(tsize))
        return idx, done, pos

    q = qlv.shape[0]
    _, _, pos = jax.lax.fori_loop(
        0, max_probes, probe,
        (idx, jnp.zeros((q,), jnp.bool_), jnp.full((q,), -1, jnp.int32)))
    out_ref[...] = pos


@functools.partial(jax.jit, static_argnames=("block", "max_probes",
                                             "interpret"))
def hash_lookup(
    h_lv: jnp.ndarray, h_u: jnp.ndarray, h_pos: jnp.ndarray,
    q_lv: jnp.ndarray, q_u: jnp.ndarray, *,
    block: int = 512, max_probes: int = MAX_PROBES, interpret: bool = True,
) -> jnp.ndarray:
    """Batched (receiver, sender) → CSR-position lookup. -1 = miss."""
    tsize = h_lv.shape[0]
    q = q_lv.shape[0]
    pad = (-q) % block
    if pad:
        q_lv = jnp.concatenate([q_lv, jnp.full(pad, -1, jnp.int32)])
        q_u = jnp.concatenate([q_u, jnp.full(pad, -1, jnp.int32)])
    grid = ((q + pad) // block,)
    out = pl.pallas_call(
        functools.partial(_lookup_kernel, tsize=tsize,
                          max_probes=max_probes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tsize,), lambda i: (0,)),   # table resident
            pl.BlockSpec((tsize,), lambda i: (0,)),
            pl.BlockSpec((tsize,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),   # queries stream
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q + pad,), jnp.int32),
        interpret=interpret,
    )(h_lv, h_u, h_pos, q_lv, q_u)
    return out[:q]
