"""Attention dispatch: Pallas kernel (TPU target) / chunked XLA / naive.

``attention`` is the single entry point used by the model zoo:
  * ``use_pallas=True``  — the flash kernel (validated in interpret mode).
  * big sequences        — ``chunked_attention``: O(S) memory online-softmax
    as a lax.scan over KV chunks.  Pure XLA, differentiable, and what the
    train/serve steps lower for the dry-runs (no S×S materialization, so the
    roofline memory term reflects a production attention).
  * small sequences      — naive einsum (fast compile for smoke tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import flash_attention

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("causal", "scale", "chunk"))
def chunked_attention(q, k, v, *, causal: bool = True,
                      scale: float | None = None, chunk: int = 1024):
    """Online-softmax attention, scanning KV chunks. GQA-aware (no repeat)."""
    b, hq, s, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    chunk = min(chunk, skv)
    assert skv % chunk == 0
    nk = skv // chunk
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, hkv, g, s, d)
    kc = k.reshape(b, hkv, nk, chunk, d)
    vc = v.reshape(b, hkv, nk, chunk, d)
    qpos = jnp.arange(s)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kj,
                            preferred_element_type=jnp.float32)
        if causal:
            kpos = j * chunk + jnp.arange(chunk)
            mask = kpos[None, :] <= qpos[:, None]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step, prevent_cse=False),
        (m0, l0, a0),
        (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0), jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, s, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "q_chunk",
                                             "kv_chunk"))
def blocked_attention(q, k, v, *, causal: bool = True,
                      scale: float | None = None, q_chunk: int = 512,
                      kv_chunk: int = 1024):
    """Double-blocked online-softmax attention (flash algorithm in XLA):
    outer scan over Q blocks, inner scan over KV blocks — live logits are
    (B,H,q_chunk,kv_chunk), so 32k×32k never materializes."""
    b, hq, s, d = q.shape
    q_chunk = min(q_chunk, s)
    assert s % q_chunk == 0
    nq = s // q_chunk
    qb = jnp.moveaxis(q.reshape(b, hq, nq, q_chunk, d), 2, 0)

    def do_q(args):
        qi, idx = args
        qpos = idx * q_chunk + jnp.arange(q_chunk)
        return _chunked_attention_pos(qi, k, v, qpos, causal=causal,
                                      scale=scale, chunk=kv_chunk)

    out = jax.lax.map(do_q, (qb, jnp.arange(nq)))
    return jnp.moveaxis(out, 0, 2).reshape(b, hq, s, d)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "chunk"))
def _chunked_attention_pos(q, k, v, qpos, *, causal, scale, chunk):
    """chunked_attention with explicit global q positions (for q-blocking)."""
    b, hq, s, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    chunk = min(chunk, skv)
    assert skv % chunk == 0
    nk = skv // chunk
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, hkv, g, s, d)
    kc = k.reshape(b, hkv, nk, chunk, d)
    vc = v.reshape(b, hkv, nk, chunk, d)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kj,
                            preferred_element_type=jnp.float32)
        if causal:
            kpos = j * chunk + jnp.arange(chunk)
            mask = kpos[None, :] <= qpos[:, None]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step, prevent_cse=False),
        (m0, l0, a0),
        (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0), jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, s, d).astype(q.dtype)


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (handles 4352-style lengths)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              use_pallas: bool = False, interpret: bool = True,
              chunk: int = 1024):
    """Main entry point. Shapes: q (B,Hq,S,D); k,v (B,Hkv,S,D)."""
    s = q.shape[2]
    skv = k.shape[2]
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, scale=scale,
                               interpret=interpret)
    if s <= 1024:
        return ref.attention(q, k, v, causal=causal, scale=scale)
    if s < 2048:
        return chunked_attention(q, k, v, causal=causal, scale=scale,
                                 chunk=_pick_chunk(skv, 512))
    return blocked_attention(q, k, v, causal=causal, scale=scale,
                             q_chunk=_pick_chunk(s, 512),
                             kv_chunk=_pick_chunk(skv, 512))
