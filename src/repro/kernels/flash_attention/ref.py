"""Pure-jnp oracle: naive causal GQA attention (O(S²) materialized)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention(q, k, v, *, causal: bool = True, scale: float | None = None):
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)

