"""Pallas TPU kernel: causal GQA flash-attention forward.

Classic online-softmax tiling adapted to the TPU memory hierarchy: Q tiles of
(BQ, D) stay resident while K/V tiles of (BK, D) stream through the
sequential innermost grid axis; the running (max, sum, acc) state lives in
VMEM scratch that persists across grid steps (TPU grids execute in order on a
core).  All matmul tiles are 128-aligned for the MXU; softmax statistics are
f32 regardless of input dtype.

GQA is handled in the BlockSpec index maps (kv head = q head // group), so no
KV replication ever materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
               *, scale, causal, bq, bk, num_kv):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_kv - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "bq", "bk", "interpret"))
def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, scale: float | None = None,
    bq: int = 128, bk: int = 128, interpret: bool = True,
) -> jnp.ndarray:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D) with Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0
    grid = (b, hq, s // bq, s // bk)
    return pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, num_kv=s // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
