"""Pallas TPU kernels: fused semiring Borůvka round body (DESIGN.md §9).

The per-round MOE election is a masked min-plus segmented SpMV: for every
fragment *f*, ``best[f] = min over incident live edges of (weight ‖ edge-id)``
in the (min, +) semiring over packed keys, where a *live* edge is one whose
endpoints lie in different fragments.  Two kernels cover the round body's
cap-scale and n-scale hot loops:

* :func:`masked_minplus_scan` — the SpMV reduction: a segmented pair-lex
  min-scan over (weight-bits, edge-id) uint32 lanes with IN-KERNEL masking
  of dead edges — the ``alive``/``where`` chain of the XLA round body never
  materializes a masked key array in HBM; each tile applies the mask on the
  fly and joins dead lanes to the scan as the semiring identity (INF).
  Extends ``kernels/segment_min``'s pair-lex scan (same Hillis–Steele
  recurrence, same SMEM carry across the sequential tiled grid) with the
  fused mask lanes.
* :func:`pointer_jump` — the merge shortcut: ⌈log2 n⌉ pointer-doubling
  gathers fused with the final fragment relabel ``parent*[comp]`` in one
  VMEM-resident launch, instead of log n + 1 separate XLA gather dispatches.

Both kernels default to ``interpret=True`` so CPU CI validates the exact
kernel semantics bit-for-bit (the repo-wide policy for kernel packages);
on TPU the same code compiles with ``interpret=False``.  The hook phase
between them is a single conflict-light n-scale scatter-min that stays in
XLA — see DESIGN.md §9 for why fragment-pair dedup (e.g. via the
``kernels/edge_hash`` probe) is unnecessary in this formulation.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF_U32 = 0xFFFFFFFF           # python int: safe to close over
SENTINEL_SEG = -2              # carry init; never a real segment id


def _minplus_kernel(seg_ref, oth_ref, hi_ref, lo_ref, ohi_ref, olo_ref,
                    carry_seg, carry_hi, carry_lo, *, block):
    """Masked segmented pair-lex min-scan tile (see module docstring).

    ``seg`` is the reducing-side fragment label (sorted), ``oth`` the other
    endpoint's fragment label riding along unsorted-in-value — the mask
    ``seg != oth`` is the Borůvka liveness test, applied here instead of in
    a separate XLA ``where`` sweep.  Padding lanes carry ``seg == oth`` (the
    ops layer pads both with the same sentinel), so they are dead by the
    same test and need no third sentinel convention.
    """
    i = pl.program_id(0)
    inf = jnp.uint32(INF_U32)
    sentinel = jnp.int32(SENTINEL_SEG)

    @pl.when(i == 0)
    def _init():
        carry_seg[0] = sentinel
        carry_hi[0] = inf
        carry_lo[0] = inf

    seg = seg_ref[...]
    oth = oth_ref[...]
    hi = hi_ref[...]
    lo = lo_ref[...]
    # In-kernel masking: dead edges (endpoints in one fragment, or the INF
    # padding key) join the scan as the semiring identity.
    live = (seg != oth) & jnp.logical_not((hi == inf) & (lo == inf))
    hi = jnp.where(live, hi, inf)
    lo = jnp.where(live, lo, inf)
    idx = jax.lax.iota(jnp.int32, block)
    # Segmented Hillis–Steele pair-lex min-scan within the tile.
    shift = 1
    while shift < block:
        shi = jnp.where(idx >= shift, jnp.roll(hi, shift), inf)
        slo = jnp.where(idx >= shift, jnp.roll(lo, shift), inf)
        sseg = jnp.where(idx >= shift, jnp.roll(seg, shift), sentinel)
        take = (sseg == seg) & ((shi < hi) | ((shi == hi) & (slo < lo)))
        hi = jnp.where(take, shi, hi)
        lo = jnp.where(take, slo, lo)
        shift *= 2
    # Fold the cross-tile carry into this tile's first run.
    ch, cl = carry_hi[0], carry_lo[0]
    take = (seg == carry_seg[0]) & ((ch < hi) | ((ch == hi) & (cl < lo)))
    hi = jnp.where(take, ch, hi)
    lo = jnp.where(take, cl, lo)
    ohi_ref[...] = hi
    olo_ref[...] = lo
    carry_seg[0] = seg[block - 1]
    carry_hi[0] = hi[block - 1]
    carry_lo[0] = lo[block - 1]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def masked_minplus_scan(
    seg: jnp.ndarray, oth: jnp.ndarray, hi: jnp.ndarray, lo: jnp.ndarray,
    *, block: int = 1024, interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Masked inclusive segmented lex-min scan along sorted ``seg`` runs.

    Lanes where ``seg == oth`` (dead edges) or ``(hi, lo) == INF`` (padding)
    contribute the identity.  The run-end elements hold each segment's
    masked min; the ops layer finalizes with a conflict-free scatter.
    """
    assert seg.shape == oth.shape == hi.shape == lo.shape and seg.ndim == 1
    m = seg.shape[0]
    assert m % block == 0, "caller pads to a block multiple"
    grid = (m // block,)
    return pl.pallas_call(
        functools.partial(_minplus_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.uint32),
            jax.ShapeDtypeStruct((m,), jnp.uint32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.int32),
            pltpu.SMEM((1,), jnp.uint32),
            pltpu.SMEM((1,), jnp.uint32),
        ],
        interpret=interpret,
    )(seg, oth, hi, lo)


def _jump_kernel(parent_ref, comp_ref, out_ref, *, num_steps):
    """Pointer-doubling shortcut + relabel, entirely VMEM-resident.

    ``num_steps`` doublings fully compress the strictly-decreasing hook
    forest (hook_min guarantees parent <= id), then the fragment labels are
    re-pointed through the compressed parent in the same launch.
    """
    p = parent_ref[...]

    def body(_, p):
        return jnp.take(p, p.astype(jnp.int32), mode="clip")

    p = jax.lax.fori_loop(0, num_steps, body, p)
    out_ref[...] = jnp.take(p, comp_ref[...].astype(jnp.int32), mode="clip")


@functools.partial(jax.jit, static_argnames=("interpret",))
def pointer_jump(
    parent: jnp.ndarray, comp: jnp.ndarray, *, interpret: bool = True,
) -> jnp.ndarray:
    """Fused full path compression + relabel: ``pointer_double(parent)[comp]``.

    Single-block launch: the (n,) parent and label arrays stay in VMEM for
    all ⌈log2 n⌉ gather steps (n ≤ ~1M uint32 fits the ~16 MB VMEM budget;
    the engines' replicated fragment-label arrays are far below that).
    """
    assert parent.ndim == 1 and comp.ndim == 1
    n = parent.shape[0]
    num_steps = max(1, math.ceil(math.log2(max(n, 2))))
    return pl.pallas_call(
        functools.partial(_jump_kernel, num_steps=num_steps),
        out_shape=jax.ShapeDtypeStruct(comp.shape, jnp.uint32),
        interpret=interpret,
    )(parent.astype(jnp.uint32), comp.astype(jnp.uint32))
