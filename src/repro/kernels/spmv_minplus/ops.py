"""jit'd dispatch for the fused Borůvka round body (DESIGN.md §9).

Three lowerings of the SAME masked min-plus election, selected statically:

* ``"scatter"`` — the XLA oracle (two scatter-mins); always available.
* ``"sort"``    — scatter-free: packs (fragment ‖ weight-bits ‖ edge-id)
  into one uint64, key-only sorts, and reads each fragment's winner with a
  ``searchsorted`` probe.  This is the fast lowering on backends where
  scatters serialize (XLA:CPU — see DESIGN.md §7/§9); gated by
  :func:`sort_gate` on the bit budget.
* ``"pallas"``  — the :mod:`.spmv_minplus` masked pair-lex scan kernel
  (sort by fragment, tiled masked segmented min-scan, conflict-free
  run-end extraction).  The accelerator lowering; interpret mode keeps the
  exact kernel semantics testable on CPU CI.

All three are exact min-reductions over identical packed keys, so they are
bit-identical by construction — tests enforce it under hypothesis-generated
layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as keys_lib
from repro.core import union_find
from repro.kernels.spmv_minplus import ref
from repro.kernels.spmv_minplus.spmv_minplus import (
    masked_minplus_scan, pointer_jump)

INF_U64 = keys_lib.INF_KEY
_PAD_SEG = np.int32(0x7FFFFFF0)
# Weight-bits budget of the sort lowering: engine weights lie in (0, 1), so
# their IEEE-754 patterns are < 0x3F800000 < 2**30 - 1 (keys.py contract).
WEIGHT_BITS = 30
WEIGHT_LIMIT_BITS = np.uint32(0x3F800000)  # ieee754_bits(1.0f)

ELECT_LOWERINGS = ("scatter", "sort", "pallas")


def sort_gate(num_vertices: int, num_edges: int) -> "tuple[int, int] | None":
    """(s_bits, c_bits) for the sort lowering, or None when fragment labels
    + 30-bit weights + edge ids cannot share one uint64 sort key.

    Callers must separately guarantee weight bits < 2**30 (true for the
    (0, 1) pipeline weights; host graphs are checked against
    ``WEIGHT_LIMIT_BITS``), which also keeps the all-ones dead sentinel
    unreachable by any live edge.
    """
    s_bits = max(int(num_vertices) - 1, 1).bit_length()
    c_bits = max(int(num_edges) - 1, 1).bit_length()
    if s_bits + WEIGHT_BITS + c_bits > 64:
        return None
    return s_bits, c_bits


def _elect_sort(cs, cd, key, *, num_segments, sort_bits):
    """Scatter-free election: key-only sort + searchsorted winner probe."""
    s_bits, c_bits = sort_bits
    shift = np.uint64(WEIGHT_BITS + c_bits)
    payload_mask = np.uint64((1 << (WEIGHT_BITS + c_bits)) - 1)
    eid_mask = np.uint64((1 << c_bits) - 1)
    ones = INF_U64

    alive = (cs != cd) & (key != INF_U64)
    # payload = (weight-bits ‖ edge-id), re-based from the 32-bit edge-id
    # lane of the engine key to the graph's actual c_bits width.
    payload = (((key >> np.uint64(32)) << np.uint64(c_bits))
               | (key & np.uint64(0xFFFFFFFF)))

    def side(seg):
        sk = (seg.astype(jnp.uint64) << shift) | payload
        return jnp.where(alive, sk, ones)

    (pk,) = jax.lax.sort((jnp.concatenate([side(cs), side(cd)]),),
                         num_keys=1)
    m2 = pk.shape[0]
    frag = jnp.arange(num_segments, dtype=jnp.uint64)
    pos = jnp.searchsorted(pk, frag << shift)
    cand = pk[jnp.minimum(pos, m2 - 1)]
    ok = (pos < m2) & ((cand >> shift) == frag) & (cand != ones)
    pay = cand & payload_mask
    best = ((pay >> np.uint64(c_bits)) << np.uint64(32)) | (pay & eid_mask)
    return jnp.where(ok, best, INF_U64)


def _elect_pallas(cs, cd, key, *, num_segments, block, interpret):
    """Kernel election: fragment-sort both directions, masked scan, run-end
    extraction with a conflict-free scatter (each slot written once)."""
    seg2 = jnp.concatenate([cs, cd]).astype(jnp.int32)
    oth2 = jnp.concatenate([cd, cs]).astype(jnp.int32)
    hi, lo = keys_lib.split_key_lanes(key)
    hi2 = jnp.concatenate([hi, hi])
    lo2 = jnp.concatenate([lo, lo])
    order = jnp.argsort(seg2)
    seg2, oth2, hi2, lo2 = (seg2[order], oth2[order], hi2[order], lo2[order])
    pad = (-seg2.shape[0]) % block
    if pad:
        # Padding lanes carry seg == oth, dead by the kernel's own mask.
        seg2 = jnp.concatenate([seg2, jnp.full(pad, _PAD_SEG, jnp.int32)])
        oth2 = jnp.concatenate([oth2, jnp.full(pad, _PAD_SEG, jnp.int32)])
        inf32 = jnp.full(pad, np.uint32(0xFFFFFFFF), jnp.uint32)
        hi2 = jnp.concatenate([hi2, inf32])
        lo2 = jnp.concatenate([lo2, inf32])
    shi, slo = masked_minplus_scan(seg2, oth2, hi2, lo2, block=block,
                                   interpret=interpret)
    scan = keys_lib.combine_key_lanes(shi, slo)
    nxt = jnp.concatenate([seg2[1:], jnp.full(1, -3, jnp.int32)])
    run_end = seg2 != nxt
    out = jnp.full((num_segments,), INF_U64, jnp.uint64)
    idx = jnp.where(run_end, seg2, num_segments)
    return out.at[idx].set(jnp.where(run_end, scan, INF_U64), mode="drop")


@functools.partial(jax.jit, static_argnames=("num_segments", "lowering",
                                             "sort_bits", "block",
                                             "interpret"))
def elect(
    cs: jnp.ndarray, cd: jnp.ndarray, key: jnp.ndarray, *,
    num_segments: int, lowering: str = "scatter",
    sort_bits: "tuple[int, int] | None" = None,
    block: int = 1024, interpret: bool = True,
) -> jnp.ndarray:
    """Per-fragment minimum-outgoing-edge election over packed uint64 keys.

    ``cs``/``cd`` are the endpoint fragment labels of every edge slot;
    ``key`` the (weight-bits ‖ edge-id) packed keys.  Returns ``best`` of
    shape (num_segments,), INF_KEY where a fragment has no live edge.
    """
    if lowering not in ELECT_LOWERINGS:
        raise ValueError(f"unknown elect lowering: {lowering!r}")
    if cs.shape[0] == 0 or num_segments == 0:
        return jnp.full((num_segments,), INF_U64, jnp.uint64)
    if lowering == "sort":
        assert sort_bits is not None, "sort lowering requires sort_bits"
        return _elect_sort(cs, cd, key, num_segments=num_segments,
                           sort_bits=sort_bits)
    if lowering == "pallas":
        return _elect_pallas(cs, cd, key, num_segments=num_segments,
                             block=block, interpret=interpret)
    return ref.elect(cs, cd, key, num_segments=num_segments)


@functools.partial(jax.jit, static_argnames=(
    "num_vertices", "axis_name", "use_pallas", "interpret",
    "collective", "cand_cap", "num_shards"))
def connected_labels(
    src: jnp.ndarray, dst: jnp.ndarray, active: jnp.ndarray, *,
    num_vertices: int, init: "jnp.ndarray | None" = None,
    axis_name: "str | None" = None,
    use_pallas: bool = False, interpret: bool = True,
    collective: str = "pmin", cand_cap: "int | None" = None,
    num_shards: int = 1,
) -> jnp.ndarray:
    """Converged connected-component labels over the active edge set.

    The batched cut/cycle probe of the filter pass (DESIGN.md §10): a
    ``lax.while_loop`` of min-hooking + pointer-jump shortcut that runs
    until no active edge crosses two components.  Every iteration with a
    crossing edge strictly reduces the component count, so the loop
    terminates; the result labels each vertex with the minimum vertex id
    of its component (a canonical labeling — comparable across callers).

    ``init`` warm-starts the loop from an existing labeling whose equal
    labels are already certified connected under ``active`` — the
    incremental path of the filter's nested threshold levels (level *j*
    refines level *j-1*'s labels, so only newly-activated edges pay
    iterations).  Canonical min-id labels stay canonical under refinement.

    ``active`` must be False on padding lanes; endpoints are clipped before
    the gather so out-of-range pad vertices (``PAD_VERTEX``) are safe.
    Under ``shard_map`` pass ``axis_name`` to combine the per-shard hook
    contributions (pmin) and the per-shard liveness flag (pmax) — the
    labels are then replicated and identical on every shard.  The body is
    also vmappable (batched probes share one compiled loop).

    ``collective="compressed"`` with a static ``cand_cap`` routes the
    hook-min through the delta exchange of
    :func:`repro.sharding.collectives.pmin_compressed` (DESIGN.md §11):
    ``hook_min`` returns the identity wherever a shard hooked nothing, so
    the identity parent array is the exchange's baseline and only actual
    hook requests travel the ring.  Exact min ⇒ labels stay bit-identical.
    """
    n = num_vertices
    src = jnp.clip(src, 0, n - 1)
    dst = jnp.clip(dst, 0, n - 1)

    def crossing(comp):
        cs = comp[src]
        cd = comp[dst]
        return cs, cd, active & (cs != cd)

    def alive_any(alive):
        more = jnp.any(alive)
        if axis_name is not None:
            more = jax.lax.pmax(more.astype(jnp.int32), axis_name) > 0
        return more

    def body(carry):
        comp, _ = carry
        cs, cd, alive = crossing(comp)
        hi = jnp.maximum(cs, cd)
        lo = jnp.minimum(cs, cd)
        parent = union_find.hook_min(n, hi, lo, alive)
        if axis_name is not None:
            if collective == "compressed" and cand_cap is not None:
                from repro.sharding import collectives
                parent = collectives.pmin_compressed(
                    parent, axis_name,
                    default=jnp.arange(n, dtype=parent.dtype),
                    cap=cand_cap, num_shards=num_shards)
            else:
                parent = jax.lax.pmin(parent, axis_name)
        comp = shortcut_relabel(parent.astype(jnp.int32), comp,
                                use_pallas=use_pallas, interpret=interpret)
        _, _, alive2 = crossing(comp)
        return comp, alive_any(alive2)

    comp0 = (jnp.arange(n, dtype=jnp.int32) if init is None
             else init.astype(jnp.int32))
    _, _, alive0 = crossing(comp0)
    comp, _ = jax.lax.while_loop(lambda c: c[1], body,
                                 (comp0, alive_any(alive0)))
    return comp


@functools.partial(jax.jit, static_argnames=(
    "num_vertices", "axis_name", "use_pallas", "interpret",
    "collective", "cand_cap", "num_shards"))
def component_maxkey(
    src: jnp.ndarray, dst: jnp.ndarray, key: jnp.ndarray,
    active: jnp.ndarray, *,
    num_vertices: int, init: "jnp.ndarray | None" = None,
    axis_name: "str | None" = None,
    use_pallas: bool = False, interpret: bool = True,
    collective: str = "pmin", cand_cap: "int | None" = None,
    num_shards: int = 1,
) -> "tuple[jnp.ndarray, jnp.ndarray]":
    """Packed max-key variant of :func:`connected_labels`.

    Runs the same warm-started hook/shortcut loop to convergence, then one
    scatter-MAX of the packed (weight ‖ edge-id) uint64 keys onto the
    converged labels.  Returns ``(comp, maxkey)`` where ``maxkey[v]`` is
    the maximum key of any active edge inside ``v``'s component (0 where
    the component has no active edge — 0 is unreachable by a live key
    because engine weights are positive).

    This is the swap bound of the incremental cycle rule (DESIGN.md §13):
    the component max dominates every tree-path max, so a probe edge whose
    endpoints share a component and whose key exceeds ``maxkey`` is the
    strict maximum of a cycle — provably non-MSF.  All comparisons happen
    in ONE graph's key space, so the bound is exact under weight ties too.

    ``init`` warm-starts the label loop exactly as in
    :func:`connected_labels` (the incremental pass seeds it with the top
    threshold level's labels, so the loop converges without iterating).
    Under ``shard_map`` the per-shard scatter-max combines with
    ``lax.pmax`` — exact max, so the replicated labels and bounds stay
    bit-identical at any shard count.
    """
    n = num_vertices
    comp = connected_labels(
        src, dst, active, num_vertices=n, init=init, axis_name=axis_name,
        use_pallas=use_pallas, interpret=interpret, collective=collective,
        cand_cap=cand_cap, num_shards=num_shards)
    # Active edges never cross components at convergence, so one endpoint
    # names the segment; inactive/padding lanes are dropped out of range.
    seg = comp[jnp.clip(src, 0, n - 1)]
    mx = jnp.zeros((n,), jnp.uint64).at[
        jnp.where(active, seg, n)
    ].max(jnp.where(active, key, jnp.uint64(0)), mode="drop")
    if axis_name is not None:
        mx = jax.lax.pmax(mx, axis_name)
    return comp, mx[comp]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def shortcut_relabel(
    parent: jnp.ndarray, comp: jnp.ndarray, *,
    use_pallas: bool = False, interpret: bool = True,
) -> jnp.ndarray:
    """Fused pointer-jumping shortcut + fragment relabel.

    Equivalent to ``union_find.pointer_double(parent)[comp]``; the Pallas
    path runs all doubling steps and the relabel in one VMEM-resident
    launch.
    """
    if not use_pallas:
        return ref.shortcut_relabel(parent, comp)
    # The kernel computes in uint32 lanes; callers carry int32 labels
    # through while_loops, so restore the label dtype.
    return pointer_jump(parent, comp, interpret=interpret).astype(comp.dtype)
