"""Pure-XLA oracles for the fused Borůvka round body (spmv_minplus)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import keys as keys_lib
from repro.core import union_find

INF_KEY = keys_lib.INF_KEY


def elect(cs: jnp.ndarray, cd: jnp.ndarray, key: jnp.ndarray,
          *, num_segments: int) -> jnp.ndarray:
    """Masked min-plus election oracle: per-fragment min packed key.

    An edge is live iff its endpoint fragments differ and its key is not the
    INF padding sentinel; dead edges contribute the semiring identity.  Both
    edge directions reduce in one pair of scatter-mins (the XLA lowering the
    kernels are benchmarked against).
    """
    alive = (cs != cd) & (key != INF_KEY)
    k = jnp.where(alive, key, INF_KEY)
    out = jnp.full((num_segments,), INF_KEY, jnp.uint64)
    out = out.at[cs].min(k, mode="drop")
    return out.at[cd].min(k, mode="drop")


def shortcut_relabel(parent: jnp.ndarray, comp: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the fused shortcut: full pointer doubling, then relabel."""
    return union_find.pointer_double(parent)[comp]
