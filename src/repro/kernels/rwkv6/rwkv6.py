"""Pallas TPU kernel: RWKV6 (Finch) WKV recurrence with data-dependent decay.

Per head with key/value dim D, the recurrence over time t is

    out_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t   = diag(w_t) S_{t-1} + k_tᵀ v_t          (w_t ∈ (0,1)^D, per-step)

The TPU-native insight: the (D, D) state S stays **resident in VMEM scratch**
for the whole sequence while time chunks of r/k/v/w stream through the
sequential grid — the GPU implementations' shared-memory tiling maps to VMEM
blocks, and HBM traffic drops to the streamed activations only.  Steps inside
a chunk are a fori_loop (the recurrence is inherently sequential in t); rank-1
updates are VPU outer products.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr,
                 *, chunk):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)                 # (D,)

    def step(t, _):
        r = r_ref[0, t].astype(jnp.float32)          # (D,)
        k = k_ref[0, t].astype(jnp.float32)
        v = v_ref[0, t].astype(jnp.float32)
        w = w_ref[0, t].astype(jnp.float32)
        s = s_scr[...]
        kv = k[:, None] * v[None, :]                 # (D, D) rank-1
        out = jnp.sum((s + u[:, None] * kv) * r[:, None], axis=0)
        s_scr[...] = w[:, None] * s + kv
        o_ref[0, t] = out.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
    u: jnp.ndarray, *, chunk: int = 128, interpret: bool = True,
) -> jnp.ndarray:
    """r,k,v,w: (BH, T, D) — batch*heads flattened; u: (BH, D) bonus.

    w is the per-step decay IN (0,1) (callers apply exp(-exp(...)))."""
    bh, t, d = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    grid = (bh, t // chunk)
    spec = pl.BlockSpec((1, chunk, d), lambda b, i: (b, i, 0))
    return pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, d), lambda b, i: (b, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, t, d), r.dtype),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
