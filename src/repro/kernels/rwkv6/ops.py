"""WKV6 dispatch: Pallas kernel / jnp scan."""
from __future__ import annotations

from repro.kernels.rwkv6 import ref
from repro.kernels.rwkv6.rwkv6 import wkv6 as wkv6_pallas


def wkv6(r, k, v, w, u, *, use_pallas: bool = False, interpret: bool = True,
         chunk: int = 128, return_state: bool = False):
    if use_pallas and not return_state:
        return wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return ref.wkv6(r, k, v, w, u, return_state=return_state)


wkv6_step = ref.wkv6_step
