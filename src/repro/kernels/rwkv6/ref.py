"""Pure-jnp oracle for the RWKV6 WKV recurrence (per-step lax.scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6(r, k, v, w, u, *, return_state: bool = False, chunk: int = 128):
    """r,k,v,w: (BH, T, D); u: (BH, D). Returns (BH, T, D) [, final state].

    Time is processed in checkpointed chunks: backward recomputes the steps
    of one chunk at a time, so residual memory is O(T/chunk · state) instead
    of O(T · state) — the XLA analogue of the Pallas kernel's chunking."""
    bh, t, d = r.shape
    c = min(chunk, t)
    while t % c:
        c -= 1
    nc = t // c

    def one_head(r, k, v, w, u):
        def step(s, x):
            rt, kt, vt, wt = x
            kv = kt[:, None] * vt[None, :]
            out = jnp.sum((s + u[:, None] * kv) * rt[:, None], axis=0)
            return wt[:, None] * s + kv, out

        @jax.checkpoint
        def chunk_fn(s, xs):
            return jax.lax.scan(step, s, xs)

        s0 = jnp.zeros((d, d), jnp.float32)
        xs = tuple(z.astype(jnp.float32).reshape(nc, c, d)
                   for z in (r, k, v, w))
        s, out = jax.lax.scan(chunk_fn, s0, xs)
        return out.reshape(t, d), s

    out, s = jax.vmap(one_head)(r, k, v, w, u.astype(jnp.float32))
    if return_state:
        return out.astype(r.dtype), s
    return out.astype(r.dtype)


def wkv6_step(s, r, k, v, w, u):
    """Single decode step: state (BH,D,D), token inputs (BH,D)."""
    kv = k[:, :, None] * v[:, None, :]
    out = jnp.sum((s + u[:, :, None] * kv) * r[:, :, None], axis=1)
    s = w[:, :, None] * s + kv
    return s, out
