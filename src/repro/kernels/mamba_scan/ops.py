"""Selective-scan dispatch: Pallas kernel / jnp scan."""
from __future__ import annotations

from repro.kernels.mamba_scan import ref
from repro.kernels.mamba_scan.mamba_scan import selective_scan \
    as selective_scan_pallas


def selective_scan(x, dt, b, c, a, d, *, use_pallas: bool = False,
                   interpret: bool = True, chunk: int = 128,
                   return_state: bool = False):
    if use_pallas and not return_state:
        return selective_scan_pallas(x, dt, b, c, a, d, chunk=chunk,
                                     interpret=interpret)
    return ref.selective_scan(x, dt, b, c, a, d, return_state=return_state)


selective_scan_step = ref.selective_scan_step
