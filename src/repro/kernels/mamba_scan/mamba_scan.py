"""Pallas TPU kernel: Mamba selective scan (S6) for the Jamba hybrid.

Per channel d and state index n:

    h_t[d,n] = exp(Δ_t[d]·A[d,n]) · h_{t-1}[d,n] + Δ_t[d]·B_t[n]·x_t[d]
    y_t[d]   = Σ_n C_t[n]·h_t[d,n] + D[d]·x_t[d]

TPU adaptation: the (BD, N) state block is VMEM-resident scratch; channel
blocks ride the parallel grid axes, time chunks the sequential one.  The
per-step update is pure VPU elementwise work + one (BD,N)×(N,) contraction;
there is no GPU-style parallel-prefix here because the TPU win is state
residency, not warp-level scan tricks (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_scr,
                 *, chunk):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)               # (BD, N)
    dvec = d_ref[...].astype(jnp.float32)            # (BD,)

    def step(t, _):
        x = x_ref[0, t].astype(jnp.float32)          # (BD,)
        dt = dt_ref[0, t].astype(jnp.float32)        # (BD,)
        bt = b_ref[0, t].astype(jnp.float32)         # (N,)
        ct = c_ref[0, t].astype(jnp.float32)         # (N,)
        h = h_scr[...]
        decay = jnp.exp(dt[:, None] * a)             # (BD, N)
        h = decay * h + (dt * x)[:, None] * bt[None, :]
        h_scr[...] = h
        y = jnp.sum(h * ct[None, :], axis=1) + dvec * x
        y_ref[0, t] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def selective_scan(
    x: jnp.ndarray, dt: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
    a: jnp.ndarray, d: jnp.ndarray, *, chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """x, dt: (B, T, D); b, c: (B, T, N); a: (D, N); d: (D,)."""
    bsz, t, dim = x.shape
    n = b.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0
    grid = (bsz, t // chunk)
    dspec = pl.BlockSpec((1, chunk, dim), lambda i, j: (i, j, 0))
    nspec = pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[dspec, dspec, nspec, nspec,
                  pl.BlockSpec((dim, n), lambda i, j: (0, 0)),
                  pl.BlockSpec((dim,), lambda i, j: (0,))],
        out_specs=dspec,
        out_shape=jax.ShapeDtypeStruct((bsz, t, dim), x.dtype),
        scratch_shapes=[pltpu.VMEM((dim, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, d)
