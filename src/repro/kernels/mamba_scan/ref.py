"""Pure-jnp oracle for the Mamba selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan(x, dt, b, c, a, d, *, return_state: bool = False,
                   chunk: int = 128):
    """x, dt: (B, T, D); b, c: (B, T, N); a: (D, N); d: (D,).

    Checkpointed time-chunking bounds backward residuals (see rwkv6/ref)."""
    af = a.astype(jnp.float32)
    df = d.astype(jnp.float32)
    t = x.shape[1]
    ck = min(chunk, t)
    while t % ck:
        ck -= 1
    nc = t // ck

    def one_batch(x, dt, b, c):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            decay = jnp.exp(dtt[:, None] * af)
            h = decay * h + (dtt * xt)[:, None] * bt[None, :]
            y = jnp.sum(h * ct[None, :], axis=1) + df * xt
            return h, y

        @jax.checkpoint
        def chunk_fn(h, xs):
            return jax.lax.scan(step, h, xs)

        h0 = jnp.zeros(af.shape, jnp.float32)
        dd, nn = x.shape[-1], b.shape[-1]
        xs = (x.astype(jnp.float32).reshape(nc, ck, dd),
              dt.astype(jnp.float32).reshape(nc, ck, dd),
              b.astype(jnp.float32).reshape(nc, ck, nn),
              c.astype(jnp.float32).reshape(nc, ck, nn))
        h, y = jax.lax.scan(chunk_fn, h0, xs)
        return y.reshape(t, dd), h

    y, h = jax.vmap(one_batch)(x, dt, b, c)
    if return_state:
        return y.astype(x.dtype), h
    return y.astype(x.dtype)


def selective_scan_step(h, x, dt, b, c, a, d):
    """Single decode step: h (B,D,N); x,dt (B,D); b,c (B,N)."""
    decay = jnp.exp(dt[:, :, None] * a[None].astype(jnp.float32))
    h = decay * h + (dt * x)[:, :, None] * b[:, None, :]
    y = jnp.sum(h * c[:, None, :], axis=2) + d[None].astype(jnp.float32) * x
    return h, y
