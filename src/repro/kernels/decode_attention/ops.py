"""Decode-attention dispatch: Pallas kernel / chunked-XLA / naive paths."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention import ref
from repro.kernels.decode_attention.decode_attention import decode_attention \
    as decode_attention_pallas

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("scale", "chunk"))
def chunked_decode_attention(q, k, v, length, *, scale: float | None = None,
                             chunk: int = 2048):
    """XLA path: lax.scan over cache chunks (O(chunk) live logits)."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    chunk = min(chunk, s)
    assert s % chunk == 0
    nk = s // chunk
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, hkv, g, d)
    kc = jnp.moveaxis(k.reshape(b, hkv, nk, chunk, d), 2, 0)
    vc = jnp.moveaxis(v.reshape(b, hkv, nk, chunk, d), 2, 0)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        logits = jnp.einsum("bhgd,bhkd->bhgk", qg, kj,
                            preferred_element_type=jnp.float32)
        kpos = j * chunk + jnp.arange(chunk)
        mask = kpos[None, None, None, :] < length[:, None, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgk,bhkd->bhgd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("scale",))
def grouped_decode_attention(q, k, v, length, *, scale: float | None = None):
    """Single-einsum decode attention without KV repeat (GQA grouped).

    The (B,H,S) logits are small even at 500k; with the cache sequence dim
    sharded over the TP axis, GSPMD lowers the softmax to local partials +
    a (B,H)-sized all-reduce — distributed flash-decode for free."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    # Operands stay in the cache dtype (bf16 on TPU) with f32 accumulation:
    # casting K/V to f32 makes XLA materialize an f32 copy of the WHOLE
    # stacked cache inside the decode loop (measured +9 GiB on phi3).
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, hkv, g, d)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg, k,
                        preferred_element_type=jnp.float32)
    mask = jnp.arange(s)[None, None, None, :] < length[:, None, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)


def decode_attention(q, k, v, length, *, scale: float | None = None,
                     use_pallas: bool = False, interpret: bool = True,
                     chunk: int = 2048):
    if use_pallas:
        return decode_attention_pallas(q, k, v, length, scale=scale,
                                       interpret=interpret)
    return grouped_decode_attention(q, k, v, length, scale=scale)
