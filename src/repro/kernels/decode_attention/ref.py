"""Pure-jnp oracle for single-token decode attention with a masked cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention(q, k, v, length, *, scale: float | None = None):
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kf) * scale
    mask = jnp.arange(s)[None, None, :] < length[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, vf).astype(q.dtype)
