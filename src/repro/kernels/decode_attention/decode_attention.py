"""Pallas TPU kernel: single-token decode attention over a KV cache.

Flash-decode adapted to GQA on TPU: the query tile packs the whole GQA head
*group* for one KV head — (group, D) — so each KV tile streamed from the
cache is read exactly once per group (the decode step is pure
memory-bandwidth; KV reuse across the group is the only lever).  Online
softmax state persists in VMEM scratch across the sequential KV grid axis.

Variable cache fill is handled with a per-batch ``length`` operand: cache
positions >= length are masked (the serving path appends tokens in place).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale, bk, num_kv):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)             # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[0], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bk", "interpret"))
def decode_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, length: jnp.ndarray, *,
    scale: float | None = None, bk: int = 512, interpret: bool = True,
) -> jnp.ndarray:
    """q: (B, Hq, D) one token; k, v: (B, Hkv, S, D) cache; length: (B,)."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(d))
    bk = min(bk, s)
    assert s % bk == 0
    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, s // bk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bk=bk,
                          num_kv=s // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
            pl.BlockSpec((1, 1, g, d), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(length.astype(jnp.int32), qg, k, v)
    return out.reshape(b, hq, d)
