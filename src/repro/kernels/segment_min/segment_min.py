"""Pallas TPU kernel: segmented min over sorted (segment, value) pairs.

The MST hot-spot (paper Report phase / our Borůvka MOE election): given
edges sorted by fragment id, compute the per-fragment minimum packed key.

TPU adaptation (DESIGN.md §2): no atomics on TPU, so instead of scatter-min
we run a *segmented inclusive min-scan* — Hillis–Steele log-steps inside each
VMEM block, with a (segment, running-min) carry threaded across the
sequential TPU grid in SMEM/VMEM scratch.  The run-ends of the scanned array
then hold each segment's min, and a conflict-free scatter (each output
written once) finalizes — that scatter lives in ops.py as plain XLA.

Block size is a multiple of 128 (VPU lane width); values are uint32 (weight
bits or tiebreak lane — two passes elect the (w, e) pair, see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF_U32 = 0xFFFFFFFF           # python int: safe to close over
SENTINEL_SEG = -2              # carry init; never a real segment id


def _scan_kernel(seg_ref, val_ref, out_ref, carry_seg, carry_val, *, block):
    i = pl.program_id(0)
    inf = jnp.uint32(INF_U32)
    sentinel = jnp.int32(SENTINEL_SEG)

    @pl.when(i == 0)
    def _init():
        carry_seg[0] = sentinel
        carry_val[0] = inf

    seg = seg_ref[...]
    val = val_ref[...]
    idx = jax.lax.iota(jnp.int32, block)
    # Segmented Hillis–Steele min-scan within the block.
    shift = 1
    while shift < block:
        sval = jnp.where(idx >= shift, jnp.roll(val, shift), inf)
        sseg = jnp.where(idx >= shift, jnp.roll(seg, shift), sentinel)
        val = jnp.where(sseg == seg, jnp.minimum(val, sval), val)
        shift *= 2
    # Fold the carry into this block's first run.
    val = jnp.where(seg == carry_seg[0], jnp.minimum(val, carry_val[0]), val)
    out_ref[...] = val
    carry_seg[0] = seg[block - 1]
    carry_val[0] = val[block - 1]


def _scan2_kernel(seg_ref, hi_ref, lo_ref, ohi_ref, olo_ref,
                  carry_seg, carry_hi, carry_lo, *, block):
    """Two-lane variant: lexicographic segmented min-scan over (hi, lo) pairs.

    This is the packed-key path — a uint64 key split into uint32 lanes so the
    scan stays in native VPU word width.  The combine is the pair-lex min
    ((hi, lo) < (hi', lo')), which is associative, so the same Hillis–Steele
    recurrence and cross-block carry as the single-lane kernel apply.
    """
    i = pl.program_id(0)
    inf = jnp.uint32(INF_U32)
    sentinel = jnp.int32(SENTINEL_SEG)

    @pl.when(i == 0)
    def _init():
        carry_seg[0] = sentinel
        carry_hi[0] = inf
        carry_lo[0] = inf

    seg = seg_ref[...]
    hi = hi_ref[...]
    lo = lo_ref[...]
    idx = jax.lax.iota(jnp.int32, block)
    shift = 1
    while shift < block:
        shi = jnp.where(idx >= shift, jnp.roll(hi, shift), inf)
        slo = jnp.where(idx >= shift, jnp.roll(lo, shift), inf)
        sseg = jnp.where(idx >= shift, jnp.roll(seg, shift), sentinel)
        take = (sseg == seg) & ((shi < hi) | ((shi == hi) & (slo < lo)))
        hi = jnp.where(take, shi, hi)
        lo = jnp.where(take, slo, lo)
        shift *= 2
    # Fold the carry into this block's first run.
    ch, cl = carry_hi[0], carry_lo[0]
    take = (seg == carry_seg[0]) & ((ch < hi) | ((ch == hi) & (cl < lo)))
    hi = jnp.where(take, ch, hi)
    lo = jnp.where(take, cl, lo)
    ohi_ref[...] = hi
    olo_ref[...] = lo
    carry_seg[0] = seg[block - 1]
    carry_hi[0] = hi[block - 1]
    carry_lo[0] = lo[block - 1]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segmented_min2_scan(
    seg: jnp.ndarray, hi: jnp.ndarray, lo: jnp.ndarray, *, block: int = 1024,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inclusive segmented lex-min scan of ``(hi, lo)`` along sorted ``seg``."""
    assert seg.shape == hi.shape == lo.shape and seg.ndim == 1
    m = seg.shape[0]
    assert m % block == 0, "caller pads to a block multiple"
    grid = (m // block,)
    return pl.pallas_call(
        functools.partial(_scan2_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.uint32),
            jax.ShapeDtypeStruct((m,), jnp.uint32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.int32),
            pltpu.SMEM((1,), jnp.uint32),
            pltpu.SMEM((1,), jnp.uint32),
        ],
        interpret=interpret,
    )(seg, hi, lo)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segmented_min_scan(
    seg: jnp.ndarray, val: jnp.ndarray, *, block: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """Inclusive segmented min-scan of ``val`` along sorted ``seg`` runs."""
    assert seg.shape == val.shape and seg.ndim == 1
    m = seg.shape[0]
    assert m % block == 0, "caller pads to a block multiple"
    grid = (m // block,)
    return pl.pallas_call(
        functools.partial(_scan_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.uint32),
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.int32),
            pltpu.SMEM((1,), jnp.uint32),
        ],
        interpret=interpret,
    )(seg, val)
