"""Pallas TPU kernel: segmented min over sorted (segment, value) pairs.

The MST hot-spot (paper Report phase / our Borůvka MOE election): given
edges sorted by fragment id, compute the per-fragment minimum packed key.

TPU adaptation (DESIGN.md §2): no atomics on TPU, so instead of scatter-min
we run a *segmented inclusive min-scan* — Hillis–Steele log-steps inside each
VMEM block, with a (segment, running-min) carry threaded across the
sequential TPU grid in SMEM/VMEM scratch.  The run-ends of the scanned array
then hold each segment's min, and a conflict-free scatter (each output
written once) finalizes — that scatter lives in ops.py as plain XLA.

Block size is a multiple of 128 (VPU lane width); values are uint32 (weight
bits or tiebreak lane — two passes elect the (w, e) pair, see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF_U32 = 0xFFFFFFFF           # python int: safe to close over
SENTINEL_SEG = -2              # carry init; never a real segment id


def _scan_kernel(seg_ref, val_ref, out_ref, carry_seg, carry_val, *, block):
    i = pl.program_id(0)
    inf = jnp.uint32(INF_U32)
    sentinel = jnp.int32(SENTINEL_SEG)

    @pl.when(i == 0)
    def _init():
        carry_seg[0] = sentinel
        carry_val[0] = inf

    seg = seg_ref[...]
    val = val_ref[...]
    idx = jax.lax.iota(jnp.int32, block)
    # Segmented Hillis–Steele min-scan within the block.
    shift = 1
    while shift < block:
        sval = jnp.where(idx >= shift, jnp.roll(val, shift), inf)
        sseg = jnp.where(idx >= shift, jnp.roll(seg, shift), sentinel)
        val = jnp.where(sseg == seg, jnp.minimum(val, sval), val)
        shift *= 2
    # Fold the carry into this block's first run.
    val = jnp.where(seg == carry_seg[0], jnp.minimum(val, carry_val[0]), val)
    out_ref[...] = val
    carry_seg[0] = seg[block - 1]
    carry_val[0] = val[block - 1]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segmented_min_scan(
    seg: jnp.ndarray, val: jnp.ndarray, *, block: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """Inclusive segmented min-scan of ``val`` along sorted ``seg`` runs."""
    assert seg.shape == val.shape and seg.ndim == 1
    m = seg.shape[0]
    assert m % block == 0, "caller pads to a block multiple"
    grid = (m // block,)
    return pl.pallas_call(
        functools.partial(_scan_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.uint32),
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.int32),
            pltpu.SMEM((1,), jnp.uint32),
        ],
        interpret=interpret,
    )(seg, val)
