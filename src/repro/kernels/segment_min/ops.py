"""jit'd wrappers for segment_min: sorted-scan Pallas path + scatter path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_min import ref
from repro.kernels.segment_min.segment_min import (
    INF_U32, segmented_min_scan)


@functools.partial(jax.jit, static_argnames=("num_segments", "block",
                                             "interpret"))
def segment_min_sorted(
    val: jnp.ndarray, seg: jnp.ndarray, *, num_segments: int,
    block: int = 1024, interpret: bool = True,
) -> jnp.ndarray:
    """Per-segment min for SORTED ``seg`` via the Pallas scan kernel.

    The run-end elements of the scan hold each segment's min; the final
    scatter is conflict-free (each output slot written exactly once)."""
    m = seg.shape[0]
    pad = (-m) % block
    if pad:
        seg = jnp.concatenate([seg, jnp.full(pad, np.int32(0x7FFFFFF0), jnp.int32)])
        val = jnp.concatenate([val, jnp.full(pad, np.uint32(0xFFFFFFFF), jnp.uint32)])
    scan = segmented_min_scan(seg, val, block=block, interpret=interpret)
    nxt = jnp.concatenate([seg[1:], jnp.full(1, -3, jnp.int32)])
    run_end = seg != nxt
    out = jnp.full((num_segments,), np.uint32(0xFFFFFFFF), jnp.uint32)
    idx = jnp.where(run_end, seg, num_segments)
    return out.at[idx].set(jnp.where(run_end, scan, np.uint32(0xFFFFFFFF)), mode="drop")


@functools.partial(jax.jit, static_argnames=("num_segments", "use_pallas",
                                             "interpret"))
def segment_min(
    val: jnp.ndarray, seg: jnp.ndarray, *, num_segments: int,
    use_pallas: bool = False, interpret: bool = True,
) -> jnp.ndarray:
    """Per-segment min; unsorted input. Pallas path sorts then scans."""
    if not use_pallas:
        return ref.segment_min(val, seg, num_segments)
    order = jnp.argsort(seg)
    return segment_min_sorted(
        val[order], seg[order], num_segments=num_segments,
        interpret=interpret)
