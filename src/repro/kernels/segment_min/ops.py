"""jit'd wrappers for segment_min: sorted-scan Pallas path + scatter path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as keys_lib
from repro.kernels.segment_min import ref
from repro.kernels.segment_min.segment_min import (
    INF_U32, segmented_min2_scan, segmented_min_scan)

INF_U64 = keys_lib.INF_KEY
_PAD_SEG = np.int32(0x7FFFFFF0)


@functools.partial(jax.jit, static_argnames=("num_segments", "block",
                                             "interpret"))
def segment_min_sorted(
    val: jnp.ndarray, seg: jnp.ndarray, *, num_segments: int,
    block: int = 1024, interpret: bool = True,
) -> jnp.ndarray:
    """Per-segment min for SORTED ``seg`` via the Pallas scan kernel.

    The run-end elements of the scan hold each segment's min; the final
    scatter is conflict-free (each output slot written exactly once)."""
    m = seg.shape[0]
    if m == 0 or num_segments == 0:
        # No input runs (or no output slots): every segment is empty and
        # gets the INF sentinel.  Never reach pallas_call with a zero grid
        # — interpret mode tolerates it, compiled lowering does not.
        return jnp.full((num_segments,), np.uint32(0xFFFFFFFF), jnp.uint32)
    pad = (-m) % block
    if pad:
        seg = jnp.concatenate([seg, jnp.full(pad, np.int32(0x7FFFFFF0), jnp.int32)])
        val = jnp.concatenate([val, jnp.full(pad, np.uint32(0xFFFFFFFF), jnp.uint32)])
    scan = segmented_min_scan(seg, val, block=block, interpret=interpret)
    nxt = jnp.concatenate([seg[1:], jnp.full(1, -3, jnp.int32)])
    run_end = seg != nxt
    out = jnp.full((num_segments,), np.uint32(0xFFFFFFFF), jnp.uint32)
    idx = jnp.where(run_end, seg, num_segments)
    return out.at[idx].set(jnp.where(run_end, scan, np.uint32(0xFFFFFFFF)), mode="drop")


@functools.partial(jax.jit, static_argnames=("num_segments", "use_pallas",
                                             "interpret"))
def segment_min(
    val: jnp.ndarray, seg: jnp.ndarray, *, num_segments: int,
    use_pallas: bool = False, interpret: bool = True,
    order: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Per-segment min; unsorted input. Pallas path sorts then scans.

    ``order`` — a precomputed ``argsort(seg)`` permutation.  Callers that run
    several reductions over the same segment array (e.g. the two-pass MOE
    election) sort once and pass the order in, instead of re-``argsort``-ing
    inside every call.
    """
    if not use_pallas:
        return ref.segment_min(val, seg, num_segments)
    if order is None:
        order = jnp.argsort(seg)
    return segment_min_sorted(
        val[order], seg[order], num_segments=num_segments,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_segments", "block",
                                             "interpret"))
def segment_min64_sorted(
    key: jnp.ndarray, seg: jnp.ndarray, *, num_segments: int,
    block: int = 1024, interpret: bool = True,
) -> jnp.ndarray:
    """Per-segment min over SORTED packed uint64 keys via the pair-lex
    Pallas scan — the key is split into uint32 lanes so the kernel stays in
    native VPU word width (requires x64 enabled for the uint64 in/out)."""
    m = seg.shape[0]
    if m == 0 or num_segments == 0:
        # Empty input / output: INF_KEY sentinels, no zero-grid kernel.
        return jnp.full((num_segments,), INF_U64, jnp.uint64)
    pad = (-m) % block
    if pad:
        seg = jnp.concatenate([seg, jnp.full(pad, _PAD_SEG, jnp.int32)])
        key = jnp.concatenate([key, jnp.full(pad, INF_U64, jnp.uint64)])
    hi, lo = keys_lib.split_key_lanes(key)
    shi, slo = segmented_min2_scan(seg, hi, lo, block=block,
                                  interpret=interpret)
    scan = keys_lib.combine_key_lanes(shi, slo)
    nxt = jnp.concatenate([seg[1:], jnp.full(1, -3, jnp.int32)])
    run_end = seg != nxt
    out = jnp.full((num_segments,), INF_U64, jnp.uint64)
    idx = jnp.where(run_end, seg, num_segments)
    return out.at[idx].set(jnp.where(run_end, scan, INF_U64), mode="drop")


@functools.partial(jax.jit, static_argnames=("num_segments", "use_pallas",
                                             "interpret"))
def segment_min64(
    key: jnp.ndarray, seg: jnp.ndarray, *, num_segments: int,
    use_pallas: bool = False, interpret: bool = True,
    order: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Per-segment min over packed uint64 keys; unsorted input.

    The fused MOE election calls this ONCE per round (both edge endpoints
    concatenated), so the Pallas path performs exactly one sort per round.
    """
    if not use_pallas:
        return ref.segment_min64(key, seg, num_segments)
    if order is None:
        order = jnp.argsort(seg)
    return segment_min64_sorted(
        key[order], seg[order], num_segments=num_segments,
        interpret=interpret)
