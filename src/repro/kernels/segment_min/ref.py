"""Pure-jnp oracle for the segment_min kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

INF_U32 = np.uint32(0xFFFFFFFF)


def segment_min(val: jnp.ndarray, seg: jnp.ndarray,
                num_segments: int) -> jnp.ndarray:
    """Per-segment min via XLA scatter-min (segments need not be sorted)."""
    out = jnp.full((num_segments,), INF_U32, jnp.uint32)
    return out.at[seg].min(val, mode="drop")


def segment_min64(key: jnp.ndarray, seg: jnp.ndarray,
                  num_segments: int) -> jnp.ndarray:
    """Per-segment min over packed uint64 keys (requires x64 enabled)."""
    inf = np.uint64(0xFFFFFFFFFFFFFFFF)
    out = jnp.full((num_segments,), inf, jnp.uint64)
    return out.at[seg].min(key, mode="drop")


def segmented_min2_scan(seg, hi, lo):
    """Pair-lex segmented min-scan oracle (sorted segments)."""
    import jax

    def step(carry, x):
        cs, ch, cl = carry
        s, h, l = x
        same = s == cs
        take = same & ((ch < h) | ((ch == h) & (cl < l)))
        h = jnp.where(take, ch, h)
        l = jnp.where(take, cl, l)
        return (s, h, l), (h, l)

    (_, _, _), (oh, ol) = jax.lax.scan(
        step, (jnp.int32(-2), INF_U32, INF_U32), (seg, hi, lo))
    return oh, ol


def segmented_min_scan(seg: jnp.ndarray, val: jnp.ndarray) -> jnp.ndarray:
    """Inclusive segmented min-scan oracle (sorted segments), O(M²) lax-free."""
    import jax

    def step(carry, x):
        cs, cv = carry
        s, v = x
        cv = jnp.where(s == cs, jnp.minimum(cv, v), v)
        return (s, cv), cv

    (_, _), out = jax.lax.scan(step, (jnp.int32(-2), INF_U32), (seg, val))
    return out
