"""Shared neural layers: norms, RoPE, GQA attention, SwiGLU — params are
plain dict pytrees (init fns + apply fns), sharding via logical tags."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention import ops as decode_ops
from repro.kernels.flash_attention import ops as attn_ops
from repro.models.config import ModelConfig
from repro.sharding.specs import shard


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def dense_init(rng, in_dim: int, out_dim: int, *, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32)
            * scale).astype(jnp.float32)


def rmsnorm(x, gamma, eps):
    xf = x.astype(jnp.float32)
    nrm = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * nrm * gamma.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, gamma, beta, eps):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (B, H, S, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (S,half)
        ang = ang[None, None]
    else:
        ang = positions.astype(jnp.float32)[:, None, :, None] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVCache:
    k: jnp.ndarray       # (B, Hkv, Smax, hd)
    v: jnp.ndarray
    index: jnp.ndarray   # scalar i32 — filled length (uniform across batch)


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "index"], meta_fields=[])


def attn_init(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 4)
    p = dict(
        wq=dense_init(ks[0], cfg.d_model, cfg.q_dim),
        wk=dense_init(ks[1], cfg.d_model, cfg.kv_dim),
        wv=dense_init(ks[2], cfg.d_model, cfg.kv_dim),
        wo=dense_init(ks[3], cfg.q_dim, cfg.d_model),
    )
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
    if cfg.qk_norm:
        p["qn"] = jnp.ones((cfg.hd,), jnp.float32)
        p["kn"] = jnp.ones((cfg.hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg: ModelConfig, x_kv=None):
    dt = x.dtype
    x_kv = x if x_kv is None else x_kv
    b, s, _ = x.shape
    skv = x_kv.shape[1]
    q = x @ p["wq"].astype(dt)
    k = x_kv @ p["wk"].astype(dt)
    v = x_kv @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, cfg.n_heads, cfg.hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, skv, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, skv, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(q, p["qn"], cfg.norm_eps)
        k = rmsnorm(k, p["kn"], cfg.norm_eps)
    return q, k, v


def attn_apply(
    p, x, cfg: ModelConfig, *, positions, causal: bool = True,
    use_rope: bool = True, x_kv=None, cache: Optional[KVCache] = None,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, x_kv=x_kv)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions if x_kv is None else
                 jnp.arange(k.shape[2]), cfg.rope_theta)
    q = shard(q, "batch", "heads", None, None)
    k = shard(k, "batch", "heads", None, None)
    v = shard(v, "batch", "heads", None, None)
    o = attn_ops.attention(q, k, v, causal=causal)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    out = o @ p["wo"].astype(x.dtype)
    if return_kv:
        return out, (k, v)
    return out


def attn_decode(
    p, x, cfg: ModelConfig, cache: KVCache, *, use_rope: bool = True,
    cross_kv=None,
):
    """One-token decode step. x: (B, 1, D)."""
    b = x.shape[0]
    if cross_kv is not None:
        k, v = cross_kv
        q = (x @ p["wq"].astype(x.dtype))
        if cfg.qkv_bias:
            q = q + p["bq"].astype(x.dtype)
        q = q.reshape(b, cfg.n_heads, cfg.hd)
        if cfg.qk_norm:
            q = rmsnorm(q, p["qn"], cfg.norm_eps)
        length = jnp.full((b,), k.shape[2], jnp.int32)
        o = decode_ops.decode_attention(q, k, v, length)
        return o.reshape(b, 1, cfg.q_dim) @ p["wo"].astype(x.dtype), cache
    q, k1, v1 = _project_qkv(p, x, cfg)
    pos = cache.index[None] if cache.index.ndim == 0 else cache.index
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k1 = rope(k1, pos, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice(
        cache.k, k1.astype(cache.k.dtype), (0, 0, cache.index, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, v1.astype(cache.v.dtype), (0, 0, cache.index, 0))
    length = jnp.full((b,), cache.index + 1, jnp.int32)
    o = decode_ops.decode_attention(q[:, :, 0], k, v, length)
    out = o.reshape(b, 1, cfg.q_dim) @ p["wo"].astype(x.dtype)
    return out, KVCache(k=k, v=v, index=cache.index + 1)


def attn_decode_stacked(p, x, cfg: ModelConfig, ks, vs, layer, index, *,
                        use_rope: bool = True):
    """One-token decode against a STACKED (L,B,Hkv,S,hd) cache, updated
    in place at (layer, index).  Used inside scan with the cache as CARRY so
    XLA aliases the buffers — one cache copy lives, not two (the xs→ys
    pattern double-buffers the whole cache)."""
    b = x.shape[0]
    q, k1, v1 = _project_qkv(p, x, cfg)
    pos = jnp.asarray(index)[None]
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k1 = rope(k1, pos, cfg.rope_theta)
    ks = jax.lax.dynamic_update_slice(
        ks, k1[None].astype(ks.dtype), (layer, 0, 0, index, 0))
    vs = jax.lax.dynamic_update_slice(
        vs, v1[None].astype(vs.dtype), (layer, 0, 0, index, 0))
    k = jax.lax.dynamic_index_in_dim(ks, layer, 0, keepdims=False)
    v = jax.lax.dynamic_index_in_dim(vs, layer, 0, keepdims=False)
    length = jnp.full((b,), index + 1, jnp.int32)
    o = decode_ops.decode_attention(q[:, :, 0], k, v, length)
    out = o.reshape(b, 1, cfg.q_dim) @ p["wo"].astype(x.dtype)
    return out, ks, vs


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               n_layers: Optional[int] = None, stacked: bool = True):
    """Zero-filled stacked KV cache: leaves have leading layer axis."""
    nl = n_layers if n_layers is not None else cfg.n_layers
    dt = cdtype(cfg)
    shape = (nl, batch, cfg.n_kv_heads, max_len, cfg.hd) if stacked else \
            (batch, cfg.n_kv_heads, max_len, cfg.hd)
    return KVCache(
        k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
        index=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(rng, d_model: int, d_ff: int) -> dict:
    ks = jax.random.split(rng, 3)
    return dict(
        wi=dense_init(ks[0], d_model, d_ff),
        wg=dense_init(ks[1], d_model, d_ff),
        wd=dense_init(ks[2], d_ff, d_model),
    )


def swiglu_apply(p, x):
    dt = x.dtype
    h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
    h = shard(h, "batch", None, "ff")
    return h @ p["wd"].astype(dt)


def embed_init(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 2)
    p = dict(embed=(jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                      jnp.float32) * 0.02))
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab)
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    x = p["embed"].astype(cdtype(cfg))[tokens]
    return shard(x, "batch", "seq", None)


def lm_logits(p, x, cfg: ModelConfig):
    w = (p["embed"].T if cfg.tie_embeddings else p["lm_head"])
    logits = x @ w.astype(x.dtype)
    return shard(logits, "batch", None, "vocab")


def chunked_lm_loss(params, x, labels, cfg, *, chunk: int = 512):
    """CE over sequence chunks: the (B, chunk, V) logits are transient and
    recomputed in backward (checkpointed) — peak memory never holds the full
    (B, S, V) logits.  This is the production head for 150k-vocab models."""
    b, s, d = x.shape
    if s % chunk != 0 or s <= chunk:
        logits = lm_logits(params, x, cfg)
        return cross_entropy(logits, labels)
    nc = s // chunk
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def step(carry, xs):
        xi, li = xs
        logits = lm_logits(params, xi, cfg)
        nll, cnt = _ce_sums(logits, li)
        return (carry[0] + nll, carry[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc))
    return nll / jnp.maximum(cnt, 1.0)


def _ce_sums(logits, labels, mask=None):
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    nll = lse - ll
    valid = (labels >= 0) if mask is None else (mask & (labels >= 0))
    valid_f = valid.astype(jnp.float32)
    return (nll * valid_f).sum(), valid_f.sum()


def cross_entropy(logits, labels, mask=None):
    """Mean CE in f32; labels -100 (or mask=0) are ignored.

    The label log-prob is a masked reduction over the vocab axis (not
    take_along_axis): with vocab sharded over the TP axis this lowers to a
    local partial sum + a tiny (B,S) all-reduce instead of an all-gather of
    the full logits."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    vocab = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    onehot = (iota == labels[..., None])
    ll = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = lse - ll
    valid = (labels >= 0) if mask is None else (mask & (labels >= 0))
    valid_f = valid.astype(jnp.float32)
    return (nll * valid_f).sum() / jnp.maximum(valid_f.sum(), 1.0)
