"""Uniform model API: family → (init, loss_fn, prefill, decode_step).

Also provides ``input_specs`` (ShapeDtypeStruct stand-ins for the dry-run)
and ``synth_batch`` (concrete random batches for smoke tests / examples).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, jamba, rwkv6, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    make_decode_state: Callable     # (cfg, batch, max_len) -> state pytree


def _transformer_state(cfg, batch, max_len):
    from repro.models.layers import make_cache
    return make_cache(cfg, batch, max_len)


def _rwkv_state(cfg, batch, max_len):
    return rwkv6.init_state(cfg, batch)


def _jamba_state(cfg, batch, max_len):
    return jamba.init_state(cfg, batch, max_len)


def _encdec_state(cfg, batch, max_len):
    from repro.models.layers import KVCache
    dt = jnp.dtype(cfg.compute_dtype)
    nl = cfg.n_layers
    cache = KVCache(
        k=jnp.zeros((nl, batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
        v=jnp.zeros((nl, batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
        index=jnp.zeros((), jnp.int32))
    # cross K/V over the encoder output (enc length == max_len here)
    cross = (jnp.zeros((nl, batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
             jnp.zeros((nl, batch, cfg.n_kv_heads, max_len, cfg.hd), dt))
    return cache, cross


def get_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelApi(transformer.init, transformer.loss_fn,
                        transformer.prefill, transformer.decode_step,
                        _transformer_state)
    if fam == "encdec":
        return ModelApi(encdec.init, encdec.loss_fn, encdec.prefill,
                        encdec.decode_step, _encdec_state)
    if fam == "ssm":
        return ModelApi(rwkv6.init, rwkv6.loss_fn, rwkv6.prefill,
                        rwkv6.decode_step, _rwkv_state)
    if fam == "hybrid":
        return ModelApi(jamba.init, jamba.loss_fn, jamba.prefill,
                        jamba.decode_step, _jamba_state)
    raise ValueError(fam)


def train_input_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs for one training batch (no allocation)."""
    specs = dict(
        tokens=jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        labels=jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    )
    if cfg.family == "encdec":
        specs["frame_embeds"] = jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_frontend), jnp.dtype(cfg.compute_dtype))
    if cfg.family == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_frontend),
            jnp.dtype(cfg.compute_dtype))
    return specs


def synth_batch(rng_seed: int, cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Concrete random batch matching ``train_input_specs``."""
    rng = np.random.default_rng(rng_seed)
    out: dict[str, Any] = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)),
                           jnp.int32),
    )
    out["labels"] = jnp.asarray(
        np.roll(np.asarray(out["tokens"]), -1, axis=1), jnp.int32)
    if cfg.family == "encdec":
        out["frame_embeds"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_frontend)) * 0.1,
            jnp.dtype(cfg.compute_dtype))
    if cfg.family == "vlm":
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_frontend_tokens,
                                 cfg.d_frontend)) * 0.1,
            jnp.dtype(cfg.compute_dtype))
    return out
