"""RWKV6 "Finch" (attention-free, data-dependent decay) — arXiv:2404.05892.

Blocks: time-mix (token-shift lerps → r/k/v/g projections, LoRA-modulated
per-channel decay w_t, WKV6 recurrence, per-head group-norm) + channel-mix
(token-shift, squared-ReLU).  The WKV core routes through
:mod:`repro.kernels.rwkv6` (Pallas TPU kernel or jnp scan).

Simplification vs. the HF release (documented in DESIGN.md): the r/k/v/g
token-shift mixes are static learned lerps (RWKV6's extra data-dependent
ddlerp LoRA is applied to the decay w only, which is where the paper's
"data-dependent decay" contribution lives).

State per layer for decode: (tm_shift (B,D), cm_shift (B,D),
wkv state (B,H,hd,hd)) — O(1) in sequence length, hence ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6 import ops as wkv_ops
from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding.specs import shard

LORA_DIM = 64


def _layer_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(rng, 12)
    hd = cfg.rwkv_head_dim
    nh = d // hd
    return dict(
        ln1=jnp.ones((d,), jnp.float32),
        ln2=jnp.ones((d,), jnp.float32),
        # time-mix
        mu_r=jnp.full((d,), 0.5, jnp.float32),
        mu_k=jnp.full((d,), 0.5, jnp.float32),
        mu_v=jnp.full((d,), 0.5, jnp.float32),
        mu_g=jnp.full((d,), 0.5, jnp.float32),
        mu_w=jnp.full((d,), 0.5, jnp.float32),
        r_proj=layers.dense_init(ks[0], d, d),
        k_proj=layers.dense_init(ks[1], d, d),
        v_proj=layers.dense_init(ks[2], d, d),
        g_proj=layers.dense_init(ks[3], d, d),
        out_proj=layers.dense_init(ks[4], d, d),
        w0=jnp.full((d,), -6.0, jnp.float32),          # decay bias
        w_lora_a=layers.dense_init(ks[5], d, LORA_DIM),
        w_lora_b=(jax.random.normal(ks[6], (LORA_DIM, d), jnp.float32)
                  * 0.01),
        u=(jax.random.normal(ks[7], (nh, hd), jnp.float32) * 0.1),
        gn=jnp.ones((d,), jnp.float32),
        gn_b=jnp.zeros((d,), jnp.float32),
        # channel-mix
        cmu_r=jnp.full((d,), 0.5, jnp.float32),
        cmu_k=jnp.full((d,), 0.5, jnp.float32),
        ck_proj=layers.dense_init(ks[8], d, cfg.d_ff),
        cv_proj=layers.dense_init(ks[9], cfg.d_ff, d),
        cr_proj=layers.dense_init(ks[10], d, d),
    )


def init(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, cfg.n_layers + 1)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg))(
        jnp.stack(ks[:-1]))
    return dict(layers=stacked,
                final_norm=jnp.ones((cfg.d_model,), jnp.float32),
                **layers.embed_init(ks[-1], cfg))


def _shift(x, prev):
    """Token shift: returns per-position previous token ([prev, x[:-1]])."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _decay(lp, xw, dt):
    w = lp["w0"].astype(jnp.float32) + jnp.tanh(
        xw.astype(jnp.float32) @ lp["w_lora_a"]) @ lp["w_lora_b"]
    return jnp.exp(-jnp.exp(w)).astype(dt)        # in (0, 1)


def _time_mix(lp, x, cfg: ModelConfig, prev_tok, wkv_state, *,
              use_pallas=False):
    """x: (B,T,D). Returns (out, new_prev_tok, new_wkv_state)."""
    b, t, d = x.shape
    hd = cfg.rwkv_head_dim
    nh = d // hd
    dt = x.dtype
    xs = _shift(x, prev_tok)
    mix = lambda mu: x + (xs - x) * mu.astype(dt)
    r = mix(lp["mu_r"]) @ lp["r_proj"].astype(dt)
    k = mix(lp["mu_k"]) @ lp["k_proj"].astype(dt)
    v = mix(lp["mu_v"]) @ lp["v_proj"].astype(dt)
    g = jax.nn.silu(mix(lp["mu_g"]) @ lp["g_proj"].astype(dt))
    w = _decay(lp, mix(lp["mu_w"]), dt)                   # (B,T,D)

    def heads(z):
        return (z.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
                .reshape(b * nh, t, hd))

    u = jnp.broadcast_to(lp["u"].astype(dt), (b, nh, hd)).reshape(b * nh, hd)
    # (B·H) rides data×model jointly: the WKV scan is independent per head,
    # so TP parallelism maps onto the flattened batch-heads dim.
    bh_shard = lambda z: shard(z, "batch_heads", None, None)
    if t == 1 and wkv_state is not None:
        s = wkv_state.reshape(b * nh, hd, hd)
        s, o = wkv_ops.wkv6_step(s, heads(r)[:, 0], heads(k)[:, 0],
                                 heads(v)[:, 0], heads(w)[:, 0], u)
        o = o[:, None].astype(dt)          # keep the residual-stream dtype
        new_state = s.astype(jnp.float32).reshape(b, nh, hd, hd)
    elif wkv_state is not None:  # prefill: thread the final state out
        o, s = wkv_ops.wkv6(bh_shard(heads(r)), bh_shard(heads(k)),
                            bh_shard(heads(v)), bh_shard(heads(w)), u,
                            return_state=True)
        new_state = s.reshape(b, nh, hd, hd)
    else:
        o = wkv_ops.wkv6(bh_shard(heads(r)), bh_shard(heads(k)),
                         bh_shard(heads(v)), bh_shard(heads(w)), u,
                         use_pallas=use_pallas)
        new_state = None   # training path does not thread state
    o = o.reshape(b, nh, t, hd).transpose(0, 2, 1, 3).reshape(b, t, d)
    o = layers.layernorm(o, lp["gn"], lp["gn_b"], cfg.norm_eps)
    out = (o * g) @ lp["out_proj"].astype(dt)
    return out, x[:, -1], new_state


def _channel_mix(lp, x, prev_tok, dt):
    xs = _shift(x, prev_tok)
    xr = x + (xs - x) * lp["cmu_r"].astype(dt)
    xk = x + (xs - x) * lp["cmu_k"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ lp["ck_proj"].astype(dt)))
    kk = shard(kk, "batch", None, "ff")
    out = jax.nn.sigmoid(xr @ lp["cr_proj"].astype(dt)) * (
        kk @ lp["cv_proj"].astype(dt))
    return out, x[:, -1]


def forward(params, tokens, cfg: ModelConfig, *, remat: str = "none",
            return_state: bool = False):
    """Training (return_state=False) / prefill (True) forward."""
    x = layers.embed_tokens(params, tokens, cfg)
    b, t, d = x.shape
    zeros_tok = jnp.zeros((b, d), x.dtype)
    nh = d // cfg.rwkv_head_dim

    def body(carry, lp):
        x, = carry
        h = layers.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        wkv0 = (jnp.zeros((b, nh, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                          jnp.float32) if return_state else None)
        o, tm, wkv = _time_mix(lp, h, cfg, zeros_tok, wkv0)
        x = x + o
        h = layers.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        o, cm = _channel_mix(lp, h, zeros_tok, x.dtype)
        x = shard(x + o, "batch", "seq", None)   # SP boundary
        ys = (tm, cm, wkv) if return_state else None
        return (x,), ys

    if remat != "none":
        from repro.models.transformer import REMAT_POLICIES
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat],
                              prevent_cse=False)
    (x,), ys = jax.lax.scan(body, (x,), params["layers"])
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_state:
        tm, cm, wkv = ys
        return x, dict(tm=tm, cm=cm, wkv=wkv)
    return x


def loss_fn(params, batch, cfg: ModelConfig, *, remat: str = "none"):
    x = forward(params, batch["tokens"], cfg, remat=remat)
    return layers.chunked_lm_loss(params, x, batch["labels"], cfg)


def init_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    dt = layers.cdtype(cfg)
    return dict(
        tm=jnp.zeros((cfg.n_layers, batch, d), dt),
        cm=jnp.zeros((cfg.n_layers, batch, d), dt),
        wkv=jnp.zeros((cfg.n_layers, batch, nh, hd, hd), jnp.float32),
    )


def prefill(params, tokens, cfg: ModelConfig, **_):
    """Run the prompt once, threading per-layer (shift, wkv) states out."""
    x, state = forward(params, tokens, cfg, return_state=True)
    logits = layers.lm_logits(params, x[:, -1:], cfg)
    return logits, state


def decode_step(params, state, tokens, cfg: ModelConfig):
    """tokens (B,1); state from init_state/prefill."""
    x = layers.embed_tokens(params, tokens, cfg)

    def body(x, xs):
        lp, tm, cm, wkv = xs
        h = layers.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        o, tm2, wkv2 = _time_mix(lp, h, cfg, tm, wkv)
        x = x + o
        h = layers.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        o, cm2 = _channel_mix(lp, h, cm, x.dtype)
        x = x + o
        return x, (tm2, cm2, wkv2)

    x, (tm, cm, wkv) = jax.lax.scan(
        body, x, (params["layers"], state["tm"], state["cm"], state["wkv"]))
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_logits(params, x, cfg)
    return logits, dict(tm=tm, cm=cm, wkv=wkv)
