"""Decoder-only transformer LM (dense / MoE / VLM-prefix families).

Layer stacks are ``lax.scan`` over stacked parameters (compact HLO at 64
layers — essential for 512-device dry-run compiles), with configurable
rematerialization of the layer body.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers, moe
from repro.models.config import ModelConfig
from repro.models.layers import KVCache
from repro.sharding.specs import shard

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def _is_moe(cfg: ModelConfig, _layer: int = 0) -> bool:
    return cfg.n_experts > 0


def layer_init(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 3)
    p = dict(
        ln1=jnp.ones((cfg.d_model,), jnp.float32),
        ln2=jnp.ones((cfg.d_model,), jnp.float32),
        attn=layers.attn_init(ks[0], cfg),
    )
    if _is_moe(cfg):
        p["moe"] = moe.moe_init(ks[1], cfg)
    else:
        p["mlp"] = layers.swiglu_init(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, cfg.n_layers + 2)
    stacked = jax.vmap(lambda k: layer_init(k, cfg))(
        jnp.stack(ks[:cfg.n_layers]))
    p = dict(
        layers=stacked,
        final_norm=jnp.ones((cfg.d_model,), jnp.float32),
        **layers.embed_init(ks[-1], cfg),
    )
    if cfg.d_frontend:
        p["patch_proj"] = layers.dense_init(
            ks[-2], cfg.d_frontend, cfg.d_model)
    return p


def _layer_apply(lp, x, cfg: ModelConfig, positions):
    h = layers.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    x = x + layers.attn_apply(lp["attn"], h, cfg, positions=positions)
    h = layers.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if _is_moe(cfg):
        y, aux = moe.moe_apply(lp["moe"], h, cfg)
    else:
        y, aux = layers.swiglu_apply(lp["mlp"], h), jnp.float32(0.0)
    x = shard(x + y, "batch", "seq", None)   # Megatron-style SP boundary
    return x, aux


def forward(params, tokens, cfg: ModelConfig, *, prefix_embeds=None,
            remat: str = "none"):
    """Returns final hidden states (B, S_total, D) and summed aux loss."""
    x = layers.embed_tokens(params, tokens, cfg)
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(x.dtype) @ params["patch_proj"].astype(
            x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        x, aux = carry
        x, a = _layer_apply(lp, x, cfg, positions)
        return (x, aux + a), None

    if remat != "none":
        body = jax.checkpoint(
            body, policy=REMAT_POLICIES[remat],
            prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               params["layers"])
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def loss_fn(params, batch, cfg: ModelConfig, *, remat: str = "none"):
    """batch: tokens (B,S), labels (B,S) [-100 = ignore], optional
    patch_embeds (B,P,d_frontend)."""
    prefix = batch.get("patch_embeds")
    x, aux = forward(params, batch["tokens"], cfg, prefix_embeds=prefix,
                     remat=remat)
    if prefix is not None:
        x = x[:, prefix.shape[1]:]
    return layers.chunked_lm_loss(params, x, batch["labels"], cfg) + aux


def prefill(params, tokens, cfg: ModelConfig, *, max_len: int,
            prefix_embeds=None):
    """Run the prompt, build stacked KV caches, return last-token logits."""
    x = layers.embed_tokens(params, tokens, cfg)
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(x.dtype) @ params["patch_proj"].astype(
            x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    pad = max_len - s

    def body(x, lp):
        h = layers.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        a, (k, v) = layers.attn_apply(
            lp["attn"], h, cfg, positions=positions, return_kv=True)
        x = x + a
        h = layers.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if _is_moe(cfg):
            y, _ = moe.moe_apply(lp["moe"], h, cfg)
        else:
            y = layers.swiglu_apply(lp["mlp"], h)
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x + y, (kp, vp)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_logits(params, x[:, -1:], cfg)
    cache = KVCache(k=ks, v=vs, index=jnp.asarray(s, jnp.int32))
    return logits, cache


def decode_step(params, cache: KVCache, tokens, cfg: ModelConfig):
    """tokens: (B, 1). Returns (logits (B,1,V), updated cache).

    The stacked cache is scan CARRY, updated in place per layer — XLA
    aliases carry buffers, so exactly one cache copy is live."""
    x = layers.embed_tokens(params, tokens, cfg)

    def body(carry, xs):
        x, ks, vs = carry
        lp, i = xs
        h = layers.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        a, ks, vs = layers.attn_decode_stacked(
            lp["attn"], h, cfg, ks, vs, i, cache.index)
        x = x + a
        h = layers.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if _is_moe(cfg):
            y, _ = moe.moe_apply(lp["moe"], h, cfg)
        else:
            y = layers.swiglu_apply(lp["mlp"], h)
        return (x + y, ks, vs), None

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache.k, cache.v),
        (params["layers"], jnp.arange(cfg.n_layers)))
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_logits(params, x, cfg)
    return logits, KVCache(k=ks, v=vs, index=cache.index + 1)
