"""Encoder-decoder backbone (Seamless-M4T large v2 text/speech backbone).

Per the assignment spec the modality frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, S_enc, d_frontend) supplied by
``input_specs()``; everything downstream (24 enc + 24 dec transformer layers,
cross-attention, vocab 256206 head) is real.  Positional encoding is RoPE
(substrate-uniform; deviation from the original sinusoidal noted in
DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import KVCache
from repro.sharding.specs import shard


def _enc_layer_init(rng, cfg):
    ks = jax.random.split(rng, 2)
    return dict(
        ln1=jnp.ones((cfg.d_model,), jnp.float32),
        ln2=jnp.ones((cfg.d_model,), jnp.float32),
        attn=layers.attn_init(ks[0], cfg),
        mlp=layers.swiglu_init(ks[1], cfg.d_model, cfg.d_ff),
    )


def _dec_layer_init(rng, cfg):
    ks = jax.random.split(rng, 3)
    return dict(
        ln1=jnp.ones((cfg.d_model,), jnp.float32),
        ln2=jnp.ones((cfg.d_model,), jnp.float32),
        ln3=jnp.ones((cfg.d_model,), jnp.float32),
        attn=layers.attn_init(ks[0], cfg),
        xattn=layers.attn_init(ks[1], cfg),
        mlp=layers.swiglu_init(ks[2], cfg.d_model, cfg.d_ff),
    )


def init(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 4)
    enc_ks = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_ks = jax.random.split(ks[1], cfg.n_layers)
    return dict(
        enc_layers=jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_ks),
        dec_layers=jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_ks),
        enc_norm=jnp.ones((cfg.d_model,), jnp.float32),
        final_norm=jnp.ones((cfg.d_model,), jnp.float32),
        frame_proj=layers.dense_init(ks[2], cfg.d_frontend, cfg.d_model),
        **layers.embed_init(ks[3], cfg),
    )


def encode(params, frame_embeds, cfg: ModelConfig, *, remat: str = "none"):
    """frame_embeds: (B, S_enc, d_frontend) — stub modality features."""
    dt = layers.cdtype(cfg)
    x = frame_embeds.astype(dt) @ params["frame_proj"].astype(dt)
    x = shard(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = layers.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + layers.attn_apply(lp["attn"], h, cfg, positions=positions,
                                  causal=False)
        h = layers.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = shard(x + layers.swiglu_apply(lp["mlp"], h), "batch", "seq", None)
        return x, None

    if remat != "none":
        from repro.models.transformer import REMAT_POLICIES
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat],
                              prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layers.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, tokens, enc_out, cfg: ModelConfig, *,
                 remat: str = "none"):
    x = layers.embed_tokens(params, tokens, cfg)
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = layers.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + layers.attn_apply(lp["attn"], h, cfg, positions=positions)
        h = layers.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + layers.attn_apply(lp["xattn"], h, cfg, positions=positions,
                                  causal=False, x_kv=enc_out, use_rope=False)
        h = layers.rmsnorm(x, lp["ln3"], cfg.norm_eps)
        x = shard(x + layers.swiglu_apply(lp["mlp"], h), "batch", "seq", None)
        return x, None

    if remat != "none":
        from repro.models.transformer import REMAT_POLICIES
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat],
                              prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params, batch, cfg: ModelConfig, *, remat: str = "none"):
    """batch: frame_embeds (B,S_enc,df), tokens (B,S_dec), labels (B,S_dec)."""
    enc_out = encode(params, batch["frame_embeds"], cfg, remat=remat)
    x = decode_train(params, batch["tokens"], enc_out, cfg, remat=remat)
    return layers.chunked_lm_loss(params, x, batch["labels"], cfg)


def prefill(params, batch, cfg: ModelConfig, *, max_len: int):
    """Encode + run decoder prompt; returns (logits, self_cache, cross_kv)."""
    enc_out = encode(params, batch["frame_embeds"], cfg)
    tokens = batch["tokens"]
    x = layers.embed_tokens(params, tokens, cfg)
    s = x.shape[1]
    positions = jnp.arange(s)
    pad = max_len - s

    def body(x, lp):
        h = layers.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        a, (k, v) = layers.attn_apply(lp["attn"], h, cfg,
                                      positions=positions, return_kv=True)
        x = x + a
        h = layers.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        # cross attention: cache enc-side K/V once
        dtt = x.dtype
        ck = (enc_out @ lp["xattn"]["wk"].astype(dtt))
        cv = (enc_out @ lp["xattn"]["wv"].astype(dtt))
        if cfg.qkv_bias:
            ck = ck + lp["xattn"]["bk"].astype(dtt)
            cv = cv + lp["xattn"]["bv"].astype(dtt)
        se = enc_out.shape[1]
        b = x.shape[0]
        ck = ck.reshape(b, se, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
        cv = cv.reshape(b, se, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
        x = x + layers.attn_apply(lp["xattn"], h, cfg, positions=positions,
                                  causal=False, x_kv=enc_out, use_rope=False)
        h = layers.rmsnorm(x, lp["ln3"], cfg.norm_eps)
        x = x + layers.swiglu_apply(lp["mlp"], h)
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x, (kp, vp, ck, cv)

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"])
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_logits(params, x[:, -1:], cfg)
    cache = KVCache(k=ks, v=vs, index=jnp.asarray(s, jnp.int32))
    return logits, cache, (cks, cvs)


def decode_step(params, cache: KVCache, cross_kv, tokens, cfg: ModelConfig):
    """Self cache rides the scan carry (in place); cross K/V are read-only."""
    x = layers.embed_tokens(params, tokens, cfg)
    cks, cvs = cross_kv

    def body(carry, xs):
        x, ks, vs = carry
        lp, ck_l, cv_l, i = xs
        h = layers.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        a, ks, vs = layers.attn_decode_stacked(
            lp["attn"], h, cfg, ks, vs, i, cache.index)
        x = x + a
        h = layers.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        a, _ = layers.attn_decode(lp["xattn"], h, cfg, None,
                                  cross_kv=(ck_l, cv_l))
        x = x + a
        h = layers.rmsnorm(x, lp["ln3"], cfg.norm_eps)
        x = x + layers.swiglu_apply(lp["mlp"], h)
        return (x, ks, vs), None

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache.k, cache.v),
        (params["dec_layers"], cks, cvs, jnp.arange(cfg.n_layers)))
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_logits(params, x, cfg)
    return logits, KVCache(k=ks, v=vs, index=cache.index + 1)
