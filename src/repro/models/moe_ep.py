"""Expert-parallel MoE via explicit shard_map — the production path.

GSPMD auto-partitioning of the sort-based MoE replicates the global argsort
and gathers (measured: TB-scale buffers at 128-expert/1M-token cells), so the
distributed layer is written MANUALLY, exactly the way the paper batches
messages (C4):

  * every device holds E/|model| experts (EP over the TP axis) and a
    replica-over-model of its data-shard's tokens;
  * routing assigns tokens to **fixed-capacity per-expert buckets**
    (capacity = cf·T·k/E, Switch-style dropping, deterministic first-come
    priority) — the MoE analogue of the paper's ``MAX_MSG_SIZE`` buffers;
  * each device computes only its buckets and the combine is ONE psum over
    the model axis (+ the shared expert computed F-sharded, riding the same
    psum for free);
  * expert weights are FSDP-sharded on D at rest and all-gathered over the
    data axis just-in-time (standard FSDP unsharding).

Expert counts that don't divide the TP axis are padded with inert experts
(router logits forced to -inf), e.g. qwen2-moe's 60 → 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding.specs import _axis_size, current_ctx

NEG_INF = -1e30


def padded_experts(cfg: ModelConfig, tp: int) -> int:
    return int(-(-cfg.n_experts // tp) * tp)


def capacity(tokens: int, cfg: ModelConfig, e_pad: int) -> int:
    c = int(np.ceil(cfg.top_k * tokens * 1.25 / e_pad))
    c = max(c, min(tokens, 8))
    return min(c, tokens)


def moe_apply_ep(p, x, cfg: ModelConfig):
    """x: (B, S, D). Requires an active sharding ctx whose model axis
    divides the padded expert count."""
    ctx = current_ctx()
    mesh, rules = ctx.mesh, ctx.rules
    tp = _axis_size(mesh, rules.model)
    e_pad = p["e_wi"].shape[0]
    assert e_pad % tp == 0
    data_axes = rules.fsdp            # weights' D-dim sharding axes
    batch_axes = rules.batch
    P = jax.sharding.PartitionSpec

    b, s, d = x.shape
    if b % _axis_size(mesh, batch_axes) != 0:
        batch_axes = None             # tiny batches: replicate over data
    has_shared = "shared" in p

    def inner(xb, router, e_wi, e_wg, e_wd, *shared_parts):
        # xb: (B_loc, S, D) — replicated over model axis.
        # e_*: (E_loc, D_loc, F) / (E_loc, F, D_loc) — gather D over data.
        e_wi = jax.lax.all_gather(e_wi, data_axes, axis=1, tiled=True)
        e_wg = jax.lax.all_gather(e_wg, data_axes, axis=1, tiled=True)
        e_wd = jax.lax.all_gather(e_wd, data_axes, axis=2, tiled=True)
        e_loc = e_wi.shape[0]
        me = jax.lax.axis_index(rules.model)
        lo = me * e_loc

        bl, sl, _ = xb.shape
        t = bl * sl
        xf = xb.reshape(t, d)
        logits = xf.astype(jnp.float32) @ router          # (T, E_pad)
        e_real = cfg.n_experts
        pad_mask = jnp.arange(logits.shape[1]) >= e_real
        logits = jnp.where(pad_mask[None], NEG_INF, logits)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, cfg.top_k)      # (T, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        # Aux load-balance loss (over real experts).
        mean_p = probs[:, :e_real].mean(axis=0)
        counts = jnp.zeros((logits.shape[1],), jnp.float32).at[
            eidx.reshape(-1)].add(1.0 / (t * cfg.top_k))
        aux = (e_real * jnp.sum(mean_p * counts[:e_real])
               * cfg.router_aux_coef)

        # Fixed-capacity buckets for MY experts (C4 aggregation analogue).
        cap = capacity(t, cfg, e_pad)
        local = eidx - lo                                  # (T, k)
        mine = (local >= 0) & (local < e_loc)
        slot = jnp.arange(t * cfg.top_k, dtype=jnp.float32)
        # score[e, t*k]: first-come priority for assigned slots
        le = jnp.where(mine, local, e_loc).reshape(-1)     # (T*k,)
        onehot = (le[None, :] == jnp.arange(e_loc)[:, None])
        score = jnp.where(onehot, -slot[None, :], NEG_INF)
        _, picked = jax.lax.top_k(score, cap)              # (E_loc, cap)
        valid = jnp.take_along_axis(
            onehot, picked, axis=1)                        # (E_loc, cap)
        token_of = picked // cfg.top_k
        g = gate.reshape(-1)[picked] * valid               # (E_loc, cap)

        xe = xf[token_of]                                  # (E_loc, cap, D)
        dt = xb.dtype
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, e_wg.astype(dt)))
             * jnp.einsum("ecd,edf->ecf", xe, e_wi.astype(dt)))
        ye = jnp.einsum("ecf,efd->ecd", h, e_wd.astype(dt))
        contrib = ye.astype(jnp.float32) * g[..., None]
        out = jnp.zeros((t, d), jnp.float32).at[
            token_of.reshape(-1)].add(contrib.reshape(-1, d))

        if has_shared:
            swi, swg, swd, sgate = shared_parts
            # F-sharded shared expert: partial sums ride the same psum.
            hs = (jax.nn.silu(xf @ swg.astype(dt)) * (xf @ swi.astype(dt)))
            ys = hs @ swd.astype(dt)
            sg = jax.nn.sigmoid(xf.astype(jnp.float32) @ sgate)
            # ys is a PARTIAL sum over this shard's F slice; sgate is linear
            # in ys, so the psum below completes the shared expert too.
            out = out + ys.astype(jnp.float32) * sg
        # Combine in the compute dtype (bf16 in production): halves the
        # largest collective of MoE cells; local accumulation stays f32.
        out = jax.lax.psum(out.astype(dt), rules.model)
        aux = jax.lax.pmean(aux, rules.model)
        return out.reshape(bl, sl, d), aux

    in_specs = [
        P(batch_axes, None, None),                     # x
        P(),                                           # router
        P(rules.model, data_axes, None),               # e_wi
        P(rules.model, data_axes, None),               # e_wg
        P(rules.model, None, data_axes),               # e_wd
    ]
    args = [x, p["router"], p["e_wi"], p["e_wg"], p["e_wd"]]
    if has_shared:
        in_specs += [P(None, rules.model), P(None, rules.model),
                     P(rules.model, None), P()]
        args += [p["shared"]["wi"], p["shared"]["wg"], p["shared"]["wd"],
                 p["shared_gate"]]
    fn = compat.shard_map(
        inner, mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(batch_axes, None, None), P()),
    )
    out, aux = fn(*args)
    return out, aux
