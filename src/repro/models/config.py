"""Model configuration shared by all ten architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | encdec | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    compute_dtype: str = "bfloat16"
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0           # per-expert ffn dim
    n_shared: int = 0           # shared experts (qwen2-moe)
    d_shared: int = 0           # shared-expert ffn dim
    moe_every: int = 1          # MoE cadence over layers (jamba: 2)
    router_aux_coef: float = 0.001
    # --- encoder-decoder (seamless) ---
    n_enc_layers: int = 0
    # --- SSM / hybrid ---
    attn_every: int = 0         # jamba: attention at layer i % 8 == 4
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    rwkv_head_dim: int = 64
    # --- VLM / audio stubs ---
    n_frontend_tokens: int = 0  # patch/frame embeddings prepended (stub)
    d_frontend: int = 0         # raw frontend feature dim (projected to d_model)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def d_inner(self) -> int:   # mamba inner dim
        return self.expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        dense_mlp = 3 * d * self.d_ff
        moe_mlp = (self.n_experts * 3 * d * self.d_expert
                   + (3 * d * self.d_shared if self.n_shared else 0)
                   + d * self.n_experts)
        if self.family in ("dense", "vlm"):
            core = self.n_layers * (attn + dense_mlp)
        elif self.family == "moe":
            core = self.n_layers * (attn + moe_mlp)
        elif self.family == "encdec":
            core = (self.n_enc_layers * (attn + dense_mlp)
                    + self.n_layers * (2 * attn + dense_mlp))
        elif self.family == "ssm":   # rwkv6
            tm = 6 * d * d          # r,k,v,w(lora approx),g,out
            cm = 2 * d * int(self.d_ff)
            core = self.n_layers * (tm + cm)
        elif self.family == "hybrid":  # jamba
            di = self.d_inner
            mamba = (2 * d * di + di * d
                     + di * (2 * self.d_state + 1) + di * self.d_conv)
            n_attn = self.n_layers // max(self.attn_every, 1)
            n_moe = self.n_layers // max(self.moe_every, 1)
            n_mamba = self.n_layers - n_attn
            core = (n_attn * attn + n_mamba * mamba
                    + n_moe * moe_mlp
                    + (self.n_layers - n_moe) * dense_mlp)
        else:
            raise ValueError(self.family)
        return emb + core

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        full_moe = self.n_experts * 3 * d * self.d_expert
        act_moe = self.top_k * 3 * d * self.d_expert
        n_moe = (self.n_layers // max(self.moe_every, 1))
        return self.param_count() - n_moe * (full_moe - act_moe)
