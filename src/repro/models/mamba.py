"""Mamba-1 (S6) mixer layer for the Jamba hybrid (arXiv:2403.19887).

in_proj → depthwise causal conv1d → selective scan (via
:mod:`repro.kernels.mamba_scan`) → gated output.  Decode carries a
(conv window, SSM state) pair per layer — O(1) in sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mamba_scan import ops as scan_ops
from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding.specs import shard


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def mamba_init(rng, cfg: ModelConfig) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    r = dt_rank(cfg)
    ks = jax.random.split(rng, 6)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    return dict(
        in_proj=layers.dense_init(ks[0], d, 2 * di),
        conv_w=(jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32)
                * (1.0 / np.sqrt(cfg.d_conv))),
        conv_b=jnp.zeros((di,), jnp.float32),
        x_proj=layers.dense_init(ks[2], di, r + 2 * n),
        dt_proj=layers.dense_init(ks[3], r, di, scale=r ** -0.5),
        dt_bias=jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,)) * 0.1, 1e-3, 0.1))),
        a_log=jnp.log(a),
        d_skip=jnp.ones((di,), jnp.float32),
        out_proj=layers.dense_init(ks[5], di, d),
    )


def _conv1d(x, w, b):
    """Depthwise causal conv. x: (B,T,di); w: (K,di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b.astype(x.dtype)


def mamba_apply(p, x, cfg: ModelConfig, *, return_state: bool = False):
    """x: (B,T,D). Returns y [, (conv_state, ssm_state)]."""
    di, n = cfg.d_inner, cfg.d_state
    r = dt_rank(cfg)
    dt_ = x.dtype
    xz = x @ p["in_proj"].astype(dt_)
    # Channel-parallel: the recurrence is elementwise over d_inner, so shard
    # channels over the TP axis (seq must be local for the time scan).
    xz = shard(xz, "batch", None, "ff")
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = jax.nn.silu(_conv1d(x1, p["conv_w"], p["conv_b"]))
    x1 = shard(x1, "batch", None, "ff")
    dbc = x1 @ p["x_proj"].astype(dt_)
    dtr, bmat, cmat = jnp.split(dbc, [r, r + n], axis=-1)
    dt_t = jax.nn.softplus(
        dtr.astype(jnp.float32) @ p["dt_proj"] + p["dt_bias"])
    dt_t = shard(dt_t, "batch", None, "ff")
    a = -jnp.exp(p["a_log"])
    res = scan_ops.selective_scan(
        x1.astype(jnp.float32), dt_t, bmat.astype(jnp.float32),
        cmat.astype(jnp.float32), a, p["d_skip"],
        return_state=return_state)
    if return_state:
        y, h = res
    else:
        y = res
    y = (y.astype(dt_) * jax.nn.silu(z)) @ p["out_proj"].astype(dt_)
    if return_state:
        k = cfg.d_conv
        xz_tail = (x @ p["in_proj"].astype(dt_))[:, -(k - 1):, :di]
        conv_state = xz_tail  # last K-1 pre-conv inputs
        return y, (conv_state, h)
    return y


def mamba_step(p, x, cfg: ModelConfig, state):
    """x: (B,1,D); state = (conv_state (B,K-1,di), ssm_state (B,di,N))."""
    conv_state, h = state
    di, n = cfg.d_inner, cfg.d_state
    r = dt_rank(cfg)
    dt_ = x.dtype
    xz = x @ p["in_proj"].astype(dt_)
    x1, z = jnp.split(xz[:, 0], 2, axis=-1)        # (B, di)
    window = jnp.concatenate([conv_state, x1[:, None]], axis=1)  # (B,K,di)
    xc = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                    p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc).astype(dt_)
    dbc = xc @ p["x_proj"].astype(dt_)
    dtr, bvec, cvec = jnp.split(dbc, [r, r + n], axis=-1)
    dt_t = jax.nn.softplus(
        dtr.astype(jnp.float32) @ p["dt_proj"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    h, y = scan_ops.selective_scan_step(
        h, xc.astype(jnp.float32), dt_t, bvec.astype(jnp.float32),
        cvec.astype(jnp.float32), a, p["d_skip"])
    y = (y.astype(dt_) * jax.nn.silu(z)) @ p["out_proj"].astype(dt_)
    new_conv = window[:, 1:]
    return y[:, None], (new_conv, h)


def init_state(cfg: ModelConfig, batch: int):
    dt_ = layers.cdtype(cfg)
    return (jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dt_),
            jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32))
