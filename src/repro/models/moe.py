"""Mixture-of-Experts layer: top-k router + sort-based ragged_dot experts.

Expert parallelism rides the ``model`` mesh axis (experts sharded on their
leading dim); tokens stay batch-sharded.  GSPMD lowers the ragged_dot pair to
per-shard expert compute + activation-sized all-reduces — no expert-weight
gathering (verified in HLO; see DESIGN.md).  The fixed-capacity bucket view
of this dispatch is the paper's C4 message-aggregation pattern applied to
token routing (DESIGN §Arch-applicability).

FLOPs are exact (2·T·k·D·F per matmul) — no one-hot dispatch einsum waste —
which keeps the roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding.specs import shard


EXPERT_PAD = 16   # pad expert count to a multiple of this (TP axis <= 16)


def moe_init(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_expert
    e_pad = -(-e // EXPERT_PAD) * EXPERT_PAD   # inert padding experts
    scale = 1.0 / jnp.sqrt(d)
    p = dict(
        router=layers.dense_init(ks[0], d, e_pad),
        e_wi=(jax.random.normal(ks[1], (e_pad, d, f), jnp.float32) * scale),
        e_wg=(jax.random.normal(ks[2], (e_pad, d, f), jnp.float32) * scale),
        e_wd=(jax.random.normal(ks[3], (e_pad, f, d), jnp.float32)
              * (1.0 / jnp.sqrt(f))),
    )
    if cfg.n_shared:
        p["shared"] = layers.swiglu_init(ks[4], d, cfg.d_shared)
        p["shared_gate"] = layers.dense_init(ks[4], d, 1)
    return p


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D), plus router aux loss.

    Dispatch: explicit expert-parallel shard_map when a sharding context is
    active (production path, see moe_ep.py); exact sort-based ragged_dot
    otherwise (single-device / smoke / oracle path)."""
    from repro.sharding.specs import _axis_size, current_ctx
    ctx = current_ctx()
    if ctx is not None and ctx.rules.model is not None:
        tp = _axis_size(ctx.mesh, ctx.rules.model)
        if tp > 1 and p["e_wi"].shape[0] % tp == 0:
            from repro.models.moe_ep import moe_apply_ep
            return moe_apply_ep(p, x, cfg)
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    e_pad = p["e_wi"].shape[0]
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E_pad) f32
    logits = jnp.where(jnp.arange(e_pad)[None] >= e, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Aux load-balance loss (Switch-style): E * Σ_e f_e · p_e.
    me = probs[:, :e].mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef

    # Sort token-replicas by expert; ragged grouped matmuls.
    flat_expert = expert_idx.reshape(-1)                     # (T*k,)
    order = jnp.argsort(flat_expert)
    token_of = order // k
    xs = xf[token_of]                                        # (T*k, D)
    xs = shard(xs, "batch", None)
    gs = jnp.bincount(flat_expert, length=e_pad)
    h = jax.lax.ragged_dot(xs, p["e_wg"].astype(x.dtype), gs)
    h2 = jax.lax.ragged_dot(xs, p["e_wi"].astype(x.dtype), gs)
    h = jax.nn.silu(h) * h2
    ys = jax.lax.ragged_dot(h, p["e_wd"].astype(x.dtype), gs)  # (T*k, D)
    # Unsort and combine with gates.
    gates_sorted = gate_vals.reshape(-1)[order].astype(jnp.float32)
    contrib = ys.astype(jnp.float32) * gates_sorted[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[token_of].add(contrib)
    out = out.astype(x.dtype)

    if cfg.n_shared:
        sg = jax.nn.sigmoid(
            (xf.astype(jnp.float32) @ p["shared_gate"]))
        out = out + (layers.swiglu_apply(p["shared"], xf)
                     * sg.astype(x.dtype))
    return out.reshape(b, s, d), aux
