"""Jamba v0.1 hybrid: Mamba + attention (1:7) with interleaved MoE (16e top-2).

Layer l ∈ [0, 32): mixer = attention iff l % 8 == 4 else Mamba;
MLP = MoE iff l % 2 == 1 else dense — exactly the published block pattern
(arXiv:2403.19887).  The stack is a lax.scan over 4 *superblocks* of 8
sublayers each (pattern identical across superblocks), keeping the HLO
compact while allowing heterogeneous layer types.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, mamba, moe
from repro.models.config import ModelConfig
from repro.models.layers import KVCache
from repro.sharding.specs import shard

SUPER = 8                 # sublayers per superblock
ATTN_POS = 4              # attention at index 4 within each superblock
MOE_POS = (1, 3, 5, 7)    # MoE at odd indices
FF_POS = (0, 2, 4, 6)


def _superblock_init(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 24)
    mamba_ks = [ks[i] for i in range(7)]
    return dict(
        mamba=jax.vmap(lambda k: mamba.mamba_init(k, cfg))(
            jnp.stack(mamba_ks)),
        attn=layers.attn_init(ks[8], cfg),
        moe=jax.vmap(lambda k: moe.moe_init(k, cfg))(
            jnp.stack([ks[9 + i] for i in range(4)])),
        ff=jax.vmap(lambda k: layers.swiglu_init(k, cfg.d_model, cfg.d_ff))(
            jnp.stack([ks[14 + i] for i in range(4)])),
        ln_mix=jnp.ones((SUPER, cfg.d_model), jnp.float32),
        ln_mlp=jnp.ones((SUPER, cfg.d_model), jnp.float32),
    )


def init(rng, cfg: ModelConfig) -> dict:
    assert cfg.n_layers % SUPER == 0
    nb = cfg.n_layers // SUPER
    ks = jax.random.split(rng, nb + 1)
    stacked = jax.vmap(lambda k: _superblock_init(k, cfg))(
        jnp.stack(ks[:nb]))
    return dict(blocks=stacked,
                final_norm=jnp.ones((cfg.d_model,), jnp.float32),
                **layers.embed_init(ks[-1], cfg))


def _take(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _superblock_apply(bp, x, cfg: ModelConfig, positions, aux,
                      sub_remat: bool = False):
    # sub_remat: checkpoint each sublayer so the superblock backward
    # recomputes one sublayer at a time (8 heterogeneous sublayers would
    # otherwise hold their working sets simultaneously).
    ckpt = (jax.checkpoint if sub_remat else (lambda f: f))
    mi = 0
    gi = 0
    fi = 0
    for idx in range(SUPER):
        h = layers.rmsnorm(x, bp["ln_mix"][idx], cfg.norm_eps)
        if idx == ATTN_POS:
            x = x + ckpt(lambda hh, p=bp["attn"]: layers.attn_apply(
                p, hh, cfg, positions=positions))(h)
        else:
            x = x + ckpt(lambda hh, p=_take(bp["mamba"], mi):
                         mamba.mamba_apply(p, hh, cfg))(h)
            mi += 1
        h = layers.rmsnorm(x, bp["ln_mlp"][idx], cfg.norm_eps)
        if idx in MOE_POS:
            y, a = ckpt(lambda hh, p=_take(bp["moe"], gi):
                        moe.moe_apply(p, hh, cfg))(h)
            aux = aux + a
            gi += 1
        else:
            y = ckpt(lambda hh, p=_take(bp["ff"], fi):
                     layers.swiglu_apply(p, hh))(h)
            fi += 1
        x = shard(x + y, "batch", "seq", None)   # SP boundary
    return x, aux


def forward(params, tokens, cfg: ModelConfig, *, remat: str = "none"):
    x = layers.embed_tokens(params, tokens, cfg)
    positions = jnp.arange(x.shape[1])

    def body(carry, bp):
        x, aux = carry
        x, aux = _superblock_apply(bp, x, cfg, positions, aux,
                                   sub_remat=False)  # refuted: see §Perf
        return (x, aux), None

    if remat != "none":
        from repro.models.transformer import REMAT_POLICIES
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat],
                              prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               params["blocks"])
    return layers.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def loss_fn(params, batch, cfg: ModelConfig, *, remat: str = "none"):
    x, aux = forward(params, batch["tokens"], cfg, remat=remat)
    return layers.chunked_lm_loss(params, x, batch["labels"], cfg) + aux


def init_state(cfg: ModelConfig, batch: int, max_len: int):
    nb = cfg.n_layers // SUPER
    conv, ssm = mamba.init_state(cfg, batch)
    dt = layers.cdtype(cfg)
    return dict(
        conv=jnp.broadcast_to(conv, (nb, 7) + conv.shape),
        ssm=jnp.broadcast_to(ssm, (nb, 7) + ssm.shape),
        k=jnp.zeros((nb, batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
        v=jnp.zeros((nb, batch, cfg.n_kv_heads, max_len, cfg.hd), dt),
        index=jnp.zeros((), jnp.int32),
    )


def prefill(params, tokens, cfg: ModelConfig, *, max_len: int):
    """Run the prompt; thread out mamba states + attention KV caches."""
    x = layers.embed_tokens(params, tokens, cfg)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    pad = max_len - s

    def body(x, bp):
        convs, ssms = [], []
        kv = None
        mi = gi = fi = 0
        for idx in range(SUPER):
            h = layers.rmsnorm(x, bp["ln_mix"][idx], cfg.norm_eps)
            if idx == ATTN_POS:
                a, (k, v) = layers.attn_apply(
                    bp["attn"], h, cfg, positions=positions, return_kv=True)
                x = x + a
                kv = (jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
                      jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))
            else:
                y, (cs, hs) = mamba.mamba_apply(
                    _take(bp["mamba"], mi), h, cfg, return_state=True)
                x = x + y
                convs.append(cs)
                ssms.append(hs)
                mi += 1
            h = layers.rmsnorm(x, bp["ln_mlp"][idx], cfg.norm_eps)
            if idx in MOE_POS:
                y, _ = moe.moe_apply(_take(bp["moe"], gi), h, cfg)
                gi += 1
            else:
                y = layers.swiglu_apply(_take(bp["ff"], fi), h)
                fi += 1
            x = x + y
        return x, (jnp.stack(convs), jnp.stack(ssms), kv[0], kv[1])

    x, (convs, ssms, ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_logits(params, x[:, -1:], cfg)
    state = dict(conv=convs, ssm=ssms, k=ks, v=vs,
                 index=jnp.asarray(s, jnp.int32))
    return logits, state


def decode_step(params, state, tokens, cfg: ModelConfig):
    """Stacked attention caches ride the scan carry (in-place updates)."""
    x = layers.embed_tokens(params, tokens, cfg)
    index = state["index"]

    def body(carry, xs):
        x, ks, vs = carry
        bp, conv, ssm, bi = xs
        convs, ssms = [], []
        mi = gi = fi = 0
        for idx in range(SUPER):
            h = layers.rmsnorm(x, bp["ln_mix"][idx], cfg.norm_eps)
            if idx == ATTN_POS:
                a, ks, vs = layers.attn_decode_stacked(
                    bp["attn"], h, cfg, ks, vs, bi, index)
                x = x + a
            else:
                y, st = mamba.mamba_step(
                    _take(bp["mamba"], mi), h, cfg, (conv[mi], ssm[mi]))
                x = x + y
                convs.append(st[0])
                ssms.append(st[1])
                mi += 1
            h = layers.rmsnorm(x, bp["ln_mlp"][idx], cfg.norm_eps)
            if idx in MOE_POS:
                y, _ = moe.moe_apply(_take(bp["moe"], gi), h, cfg)
                gi += 1
            else:
                y = layers.swiglu_apply(_take(bp["ff"], fi), h)
                fi += 1
            x = x + y
        return (x, ks, vs), (jnp.stack(convs), jnp.stack(ssms))

    nb = cfg.n_layers // SUPER
    (x, ks, vs), (convs, ssms) = jax.lax.scan(
        body, (x, state["k"], state["v"]),
        (params["blocks"], state["conv"], state["ssm"], jnp.arange(nb)))
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.lm_logits(params, x, cfg)
    return logits, dict(conv=convs, ssm=ssms, k=ks, v=vs, index=index + 1)
