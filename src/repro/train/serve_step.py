"""Serving steps: prefill (prompt → cache) and decode (one token/step),
uniform across the ten architecture families."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.api import get_model
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, *, max_len: int):
    """Returns ``prefill_step(params, batch) -> (logits, state)`` — ALWAYS a
    2-tuple, for every family.  encdec's native ``model.prefill`` returns
    ``(logits, cache, cross)``; it is normalised here to
    ``(logits, (cache, cross))`` so the state round-trips opaquely into
    :func:`make_decode_step` (which unpacks the pair itself).  Callers must
    not probe tuple arity — that pattern mis-shaped the decode state when a
    family's native return drifted."""
    model = get_model(cfg)

    def prefill_step(params, batch):
        if cfg.family == "encdec":
            logits, cache, cross = model.prefill(params, batch, cfg,
                                                 max_len=max_len)
            return logits, (cache, cross)
        if cfg.family == "vlm":
            # cache must hold prompt + patch-prefix tokens
            return model.prefill(params, batch["tokens"], cfg,
                                 max_len=max_len + cfg.n_frontend_tokens,
                                 prefix_embeds=batch["patch_embeds"])
        if cfg.family in ("ssm",):
            return model.prefill(params, batch["tokens"], cfg)
        return model.prefill(params, batch["tokens"], cfg, max_len=max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, sample: str = "greedy",
                     temperature: float = 1.0):
    model = get_model(cfg)

    def pick(logits, rng):
        lf = logits[:, -1].astype(jnp.float32)
        if sample == "greedy":
            return jnp.argmax(lf, axis=-1).astype(jnp.int32)
        return jax.random.categorical(rng, lf / temperature).astype(jnp.int32)

    def decode_step(params, state, tokens, rng=None):
        """tokens: (B, 1) current token. Returns (next_tokens, new state)."""
        if cfg.family == "encdec":
            cache, cross = state
            logits, cache = model.decode_step(params, cache, cross, tokens,
                                              cfg)
            new_state = (cache, cross)
        else:
            logits, new_state = model.decode_step(params, state, tokens, cfg)
        nxt = pick(logits, rng)
        return nxt[:, None], new_state, logits

    return decode_step


def decode_input_specs(cfg: ModelConfig, batch: int, cache_len: int):
    """ShapeDtypeStructs for (state, tokens) of one decode step (dry-run)."""
    model = get_model(cfg)
    state = jax.eval_shape(
        lambda: model.make_decode_state(cfg, batch, cache_len))
    # state caches start "filled" at cache_len - 1 (decoding the last slot)
    tokens = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    return state, tokens
