"""Train step factory: loss → grads (remat, microbatch accumulation,
optional bf16 gradient compression with error feedback) → AdamW."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.api import get_model
from repro.models.config import ModelConfig
from repro.sharding.collectives import compress_tree
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    remat: str = "full"          # none | full | dots | dots_no_batch
    grad_accum: int = 1          # microbatch accumulation steps
    adamw: opt.AdamWConfig = opt.AdamWConfig()


def init_train_state(rng, cfg: ModelConfig) -> dict:
    params = get_model(cfg).init(rng, cfg)
    state = dict(params=params, opt=opt.init(params))
    return state


def make_train_step(cfg: ModelConfig, hp: TrainHParams):
    model = get_model(cfg)
    adamw = hp.adamw

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, cfg, remat=hp.remat)

    def train_step(state, batch):
        params = state["params"]
        if hp.grad_accum > 1:
            def split(x):
                b = x.shape[0]
                a = hp.grad_accum
                return x.reshape(a, b // a, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_sum, g_sum = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_sum + loss,
                        jax.tree.map(jnp.add, g_sum, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0.0), zeros), micro)
            loss = loss / hp.grad_accum
            grads = jax.tree.map(lambda g: g / hp.grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if adamw.compress_grads:
            grads, residual = compress_tree(
                grads, state.get("grad_residual"))
        new_params, new_opt, metrics = opt.update(
            grads, state["opt"], params, adamw)
        new_state = dict(params=new_params, opt=new_opt)
        if adamw.compress_grads:
            new_state["grad_residual"] = residual
        metrics = dict(loss=loss, **metrics)
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, hp: Optional[TrainHParams] = None):
    model = get_model(cfg)
    remat = hp.remat if hp else "none"

    def eval_step(params, batch):
        return model.loss_fn(params, batch, cfg, remat=remat)

    return eval_step
