"""Deterministic data pipeline: synthetic LM batches + binary token files.

Both sources are *stateless-resumable*: batch t is a pure function of
(seed, step), so checkpoint restore at step N reproduces the exact stream
(no iterator state to persist beyond the step counter).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str = "synthetic"     # synthetic | file
    path: Optional[str] = None  # uint16/uint32 .bin for kind=file
    vocab: int = 32000
    seed: int = 0


class SyntheticTokens:
    """Zipf-ish synthetic token stream (harder than uniform for loss curves)."""

    def __init__(self, cfg: DataConfig, batch: int, seq: int,
                 host_id: int = 0, num_hosts: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.host_id = host_id
        self.num_hosts = num_hosts
        assert batch % num_hosts == 0
        self.local_batch = batch // num_hosts

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.cfg.seed, step, self.host_id))
        z = rng.zipf(1.3, size=(self.local_batch, self.seq + 1))
        toks = (z % self.cfg.vocab).astype(np.int32)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:])

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class TokenFile:
    """Memory-mapped flat token file, sharded across hosts by stride."""

    def __init__(self, cfg: DataConfig, batch: int, seq: int,
                 host_id: int = 0, num_hosts: int = 1,
                 dtype=np.uint16):
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.batch = batch
        self.seq = seq
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = batch // num_hosts
        self.tokens_per_batch = self.local_batch * (seq + 1)
        n_windows = (len(self.data) - 1) // self.tokens_per_batch
        self.n_windows = max(n_windows, 1)

    def batch_at(self, step: int) -> dict:
        w = (step * self.num_hosts + self.host_id) % self.n_windows
        start = w * self.tokens_per_batch
        chunk = np.asarray(
            self.data[start:start + self.tokens_per_batch + 1])
        if chunk.size < self.tokens_per_batch + 1:
            chunk = np.pad(chunk,
                           (0, self.tokens_per_batch + 1 - chunk.size))
        toks = chunk[:self.tokens_per_batch].reshape(
            self.local_batch, self.seq + 1).astype(np.int32)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:])


def make_dataset(cfg: DataConfig, batch: int, seq: int, **kw):
    if cfg.kind == "synthetic":
        return SyntheticTokens(cfg, batch, seq, **kw)
    if cfg.kind == "file":
        return TokenFile(cfg, batch, seq, **kw)
    raise ValueError(cfg.kind)
