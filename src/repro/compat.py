"""Version-compat shims over the jax API surface the engines depend on.

The repo targets the modern ``jax.shard_map`` / ``jax.sharding.AxisType``
surface but must also run on older jax releases where ``shard_map`` still
lives in ``jax.experimental`` (with ``check_rep`` instead of ``check_vma``)
and meshes carry no axis types.  Every mesh/shard_map construction in the
repo goes through this module so the engines, benchmarks, and subprocess
tests agree on one spelling.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API has them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axis_names)
