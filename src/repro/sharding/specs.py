"""Logical-axis sharding rules → PartitionSpecs over the (pod, data, model) mesh.

Models are sharding-agnostic: they tag activations with *logical* axis names
via :func:`shard` (a no-op outside a :class:`ShardingCtx`), and parameter
specs are inferred from tree paths by regex rules (t5x-style), so one rules
table serves every architecture.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical axis name → mesh axes (None = replicate)."""

    batch: tuple = ("data",)          # ('pod','data') on multi-pod meshes
    model: str = "model"              # TP axis
    fsdp: tuple = ("data",)           # parameter sharding axes

    def logical(self, name: Optional[str]):
        if name is None:
            return None
        if name == "batch":
            return self.batch
        if name in ("heads", "ff", "vocab", "experts", "model", "seq"):
            return self.model
        if name == "batch_heads":   # (B·H) flattened dims: data × model
            m = (self.model,) if self.model else ()
            return tuple(self.batch) + m
        if name == "fsdp":
            return self.fsdp
        raise KeyError(name)


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: ShardingRules


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: ShardingRules):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ShardingCtx(mesh, rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_STATE, "ctx", None)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def shard(x, *logical_axes):
    """Constrain activation ``x`` to logical axes; no-op without context.

    Dims not divisible by their mesh-axis product are left unconstrained
    (GSPMD would otherwise pad — e.g. 40 heads on a 16-way TP axis)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    parts = []
    for dim, a in zip(x.shape, logical_axes):
        axes = ctx.rules.logical(a)
        if axes is not None and dim % _axis_size(ctx.mesh, axes) != 0:
            axes = None
        parts.append(axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*parts)))


# ---------------------------------------------------------------------------
# Parameter specs by tree-path regex.  Paths look like
# "layers/attn/wq", "embed", "layers/moe/w_up", ... (stacked-layer leading
# axis, if any, is handled by rank padding: rules give the TRAILING dims).
# ---------------------------------------------------------------------------

# (regex, trailing logical dims) — first match wins.
_PARAM_RULES: Sequence[tuple] = (
    (r"(^|/)embed$",        ("vocab", "fsdp")),
    (r"(^|/)lm_head$",      ("fsdp", "vocab")),
    (r"(^|/)w[qkv]$",       ("fsdp", "heads")),
    (r"(^|/)wo$",           ("heads", "fsdp")),
    (r"(^|/)(wi|wg)$",      ("fsdp", "ff")),
    (r"(^|/)wd$",           ("ff", "fsdp")),
    (r"(^|/)router$",       ("fsdp", None)),
    (r"(^|/)(e_wi|e_wg)$",  ("experts", "fsdp", None)),
    (r"(^|/)e_wd$",         ("experts", None, "fsdp")),
    # Mamba: channel dim (d_inner) is the TP axis; out_proj contracts over
    # it (standard TP pair: column-parallel in, row-parallel out).
    (r"(^|/)in_proj$",      ("fsdp", "model")),
    (r"(^|/)out_proj$",     ("model", "fsdp")),
    (r"(^|/)x_proj$",       ("model", None)),
    (r"(^|/)dt_proj$",      (None, "model")),
    (r"(^|/)(r_proj|k_proj|v_proj|g_proj|w_proj|patch_proj|frame_proj|"
     r"cr_proj)$", ("fsdp", None)),
    (r"(^|/)ck_proj$",      ("fsdp", "ff")),
    (r"(^|/)cv_proj$",      ("ff", "fsdp")),
    (r".*",                 None),   # default: replicate
)


def param_spec(path: str, shape, rules: ShardingRules,
               mesh: Optional[Mesh] = None) -> P:
    ndim = len(shape)
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            if logical is None:
                return P()
            axes = [rules.logical(a) for a in logical]
            pad = ndim - len(axes)
            if pad < 0:   # param smaller than rule (e.g. fused bias) → replicate
                return P()
            axes = [None] * pad + axes
            if mesh is not None:   # drop indivisible constraints
                axes = [None if (a is not None
                                 and shape[i] % _axis_size(mesh, a) != 0)
                        else a for i, a in enumerate(axes)]
            return P(*axes)
    return P()


def tree_paths(tree) -> dict:
    """Flatten a pytree into {path: leaf} with '/'-joined key paths."""
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}" if prefix else str(i), v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def param_shardings(params, mesh: Mesh, rules: ShardingRules):
    """Pytree of NamedShardings matching ``params`` structure."""
    paths = tree_paths(params)
    specs = {p: param_spec(p, tuple(getattr(v, "shape", ())), rules, mesh)
             for p, v in paths.items()}

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rebuild(f"{prefix}/{i}" if prefix else str(i), v)
                 for i, v in enumerate(node)]
            return type(node)(t)
        return NamedSharding(mesh, specs[prefix])

    return rebuild("", params)
