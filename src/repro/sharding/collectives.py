"""Distributed-optimization helpers: compressed gradients + overlap flags.

``compress_tree`` casts gradients to bf16 with error feedback *before* the
data-parallel reduction XLA inserts (halving DP all-reduce bytes); the
residual rides in the optimizer state so the update is unbiased over time.

``latency_hiding_flags`` returns the XLA flags that enable the
latency-hiding scheduler (compute/collective overlap) on real TPU runs;
the launcher exports them, the CPU container ignores them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_tree(grads, residual):
    """bf16 compression with error feedback. residual=None -> zeros."""
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def comp(g, r):
        gf = g.astype(jnp.float32) + r
        gc = gf.astype(jnp.bfloat16)
        return gc, gf - gc.astype(jnp.float32)

    pairs = jax.tree.map(comp, grads, residual)
    comp_g = jax.tree.map(lambda p: p[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp_g, new_res


LATENCY_HIDING_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
)


def latency_hiding_flags() -> str:
    return LATENCY_HIDING_FLAGS
