"""Sharded collectives: compressed MST reductions + LM-gradient helpers.

Two families live here:

**MST-facing compressed reductions** (DESIGN.md §11).  The per-round
exchange of the distributed Borůvka engines is an elementwise ``pmin``
over a replicated length-``n`` array (fragment MOE keys, hook parents) —
but each shard only *improves* the entries its local edges touch, and that
count shrinks geometrically as fragments merge and edges die.
:func:`pmin_compressed` exploits the sparsity: each shard packs its
improved ``(index, value)`` pairs into a static-``cap`` candidate list and
the lists travel a ``ppermute`` store-and-forward ring (P-1 steps, every
shard scatter-mins every other shard's original packet exactly once).
The reduction is an exact min over the identical value set, so the result
is bit-identical to ``lax.pmin`` by construction; when any shard's
candidate count overflows ``cap``, a replicated flag routes the WHOLE
step through ``lax.pmin`` (the fallback contract — never a truncated
exchange).  This mirrors the paper's message-compression optimization:
the exchange is sized by what changed, not by the vertex count.

**LM-gradient helpers** (the module's original residents).
``compress_tree`` casts gradients to bf16 with error feedback *before*
the data-parallel reduction XLA inserts (halving DP all-reduce bytes);
the residual rides in the optimizer state so the update is unbiased over
time.

``latency_hiding_flags`` returns the XLA flags that enable the
latency-hiding scheduler (compute/collective overlap) for TPU *and* GPU
runs; :func:`repro.platform.set_platform` exports them, the CPU container
ignores them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

COLLECTIVES = ("pmin", "compressed")

# Wire format of one candidate entry: int32 index lane + the value lane.
INDEX_BYTES = 4


def resolve_collective(collective: str) -> str:
    """Validate the shared ``params.collective`` knob."""
    if collective not in COLLECTIVES:
        raise ValueError(
            f"unknown collective {collective!r}; options: {COLLECTIVES}")
    return collective


def pmin_compressed(
    x: jnp.ndarray,
    axis_name: str,
    *,
    default: jnp.ndarray,
    cap: int,
    num_shards: int,
) -> jnp.ndarray:
    """Elementwise min over ``axis_name``, exchanging only improved entries.

    ``x`` is a per-shard length-``n`` array whose entries equal ``default``
    (a scalar sentinel like ``INF_KEY``, or an elementwise baseline like
    the identity parent array — any shape broadcastable against ``x``)
    wherever the shard contributed nothing this round.  Each shard packs
    the positions where ``x != default`` into a ``(cap,)`` candidate list
    of ``(int32 index, value)`` pairs; the packets ride a store-and-forward
    ``ppermute`` ring for ``num_shards - 1`` steps, so every shard
    scatter-mins every other shard's *original* packet exactly once.

    Exactness: the result at index ``i`` is the min over all shards'
    contributed values and the (shard-agreed) baseline — the same value
    set ``lax.pmin`` reduces, in a different order, and min over uint keys
    is order-free, so the output is bit-identical.  If ANY shard holds
    more than ``cap`` candidates, a pmax-replicated overflow flag sends
    every shard through plain ``lax.pmin`` for this call (the fallback
    contract); ``cap`` therefore tunes bytes, never correctness.
    """
    if num_shards <= 1:
        return x
    n = x.shape[0]
    has = x != default
    count = has.sum(dtype=jnp.int32)
    overflow = jax.lax.pmax((count > cap).astype(jnp.int32), axis_name) > 0

    def full(x):
        return jax.lax.pmin(x, axis_name)

    def ring(x):
        pos = jnp.cumsum(has.astype(jnp.int32)) - 1
        idx = jnp.where(has, pos, cap)          # cap → scatter-dropped
        # Index sentinel n is out of range for the accumulator scatter, so
        # unused packet slots are inert on the receiving side too.
        frag = jnp.full((cap,), n, jnp.int32).at[idx].set(
            jnp.arange(n, dtype=jnp.int32), mode="drop")
        val = jnp.zeros((cap,), x.dtype).at[idx].set(x, mode="drop")
        acc = x
        perm = [(i, (i + 1) % num_shards) for i in range(num_shards)]
        for _ in range(num_shards - 1):
            frag = jax.lax.ppermute(frag, axis_name, perm)
            val = jax.lax.ppermute(val, axis_name, perm)
            acc = acc.at[frag].min(val, mode="drop")
        return acc

    return jax.lax.cond(overflow, full, ring, x)


def compressed_bytes(cap: int, num_shards: int, value_bytes: int) -> int:
    """Per-shard on-wire bytes of ONE compressed exchange: ``num_shards-1``
    ring steps each forwarding a ``cap``-entry packet."""
    if num_shards <= 1:
        return 0
    return (num_shards - 1) * cap * (INDEX_BYTES + value_bytes)


def dense_bytes(n: int, num_shards: int, value_bytes: int) -> int:
    """Per-shard on-wire bytes of one full-width ``lax.pmin`` over a
    replicated length-``n`` array, under the bandwidth-optimal
    reduce-scatter + all-gather model: ``2·(P-1)/P · n`` values."""
    if num_shards <= 1:
        return 0
    return int(2 * (num_shards - 1) * n * value_bytes // num_shards)


def compress_tree(grads, residual):
    """bf16 compression with error feedback. residual=None -> zeros."""
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def comp(g, r):
        gf = g.astype(jnp.float32) + r
        gc = gf.astype(jnp.bfloat16)
        return gc, gf - gc.astype(jnp.float32)

    pairs = jax.tree.map(comp, grads, residual)
    comp_g = jax.tree.map(lambda p: p[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp_g, new_res


LATENCY_HIDING_FLAGS_TPU = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
)

LATENCY_HIDING_FLAGS_GPU = (
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true "
    "--xla_gpu_enable_pipelined_all_reduce=true "
    "--xla_gpu_enable_pipelined_all_gather=true "
    "--xla_gpu_enable_pipelined_reduce_scatter=true "
    "--xla_gpu_enable_while_loop_double_buffering=true "
)


def latency_hiding_flags(platform: str = "tpu") -> str:
    """XLA latency-hiding / async-collective flags for ``platform``.

    ``"tpu"`` enables the latency-hiding scheduler + async collective
    fusion; ``"gpu"`` the GPU scheduler, prioritized async streams, and
    pipelined collectives (plus while-loop double buffering, which pairs
    with the runtime's double-buffered intervals — DESIGN.md §11).
    ``"cpu"`` has no such flags and returns the empty string.
    """
    if platform == "tpu":
        return LATENCY_HIDING_FLAGS_TPU
    if platform == "gpu":
        return LATENCY_HIDING_FLAGS_GPU
    if platform == "cpu":
        return ""
    raise ValueError(f"unknown platform {platform!r}")
