"""Backend pinning for benchmarks and CI legs.

Every bench leg (and the subprocess children the shard sweeps spawn) must
pin its backend EXPLICITLY — a benchmark that silently lands on a different
platform, device count, or x64 mode produces numbers that cannot be
compared across runs.  This module is the one home for that pinning:

* :func:`set_platform` — force the jax platform (``cpu``/``gpu``/``tpu``),
  plus the allocator flags a GPU run wants pinned and the platform's
  latency-hiding / async-collective flags (:func:`latency_hiding_flags`),
  so accelerator bench legs get compute/collective overlap for free.
* :func:`force_host_device_count` — emulate an N-device host (the
  ``--xla_force_host_platform_device_count`` flag the multi-shard tests
  and sweeps rely on).
* :func:`set_debug_nan` / :func:`set_x64` — debugging & width toggles.
* :func:`pin` — one-stop shop used by ``benchmarks/common.py``.

All of these must run BEFORE jax initializes its backends; each helper
raises if called too late rather than silently doing nothing.
"""
from __future__ import annotations

import os


def _jax_initialized() -> bool:
    import sys
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:       # private API moved — assume the worst
        return True


def _require_uninitialized(what: str) -> None:
    if _jax_initialized():
        raise RuntimeError(
            f"{what} must be set before jax initializes its backends; "
            "call repro.platform helpers at process start (see "
            "benchmarks/common.py)")


def _append_xla_flags(flag: str) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if flag in flags.split():
        return
    os.environ["XLA_FLAGS"] = (flags + " " + flag).strip()


def latency_hiding_flags(platform: str = "tpu") -> str:
    """XLA latency-hiding / async-collective flags for ``platform``
    (re-exported from :mod:`repro.sharding.collectives` so bench legs can
    pin overlap without importing the sharding layer)."""
    from repro.sharding import collectives
    return collectives.latency_hiding_flags(platform)


def set_platform(platform: str = "cpu") -> None:
    """Force the jax platform; pins allocator + latency-hiding flags
    alongside (accelerator legs get compute/collective overlap for free —
    the CPU container has no such flags and skips)."""
    if platform not in ("cpu", "gpu", "tpu"):
        raise ValueError(f"unknown platform {platform!r}")
    _require_uninitialized("platform")
    for flag in latency_hiding_flags(platform).split():
        _append_xla_flags(flag)
    import jax
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        # Deterministic memory behavior for benching: no growth-on-demand
        # rescans mid-run.
        os.environ.setdefault("XLA_PYTHON_CLIENT_PREALLOCATE", "false")
        os.environ.setdefault("XLA_PYTHON_CLIENT_ALLOCATOR", "platform")


def force_host_device_count(n: int) -> None:
    """Emulate ``n`` host devices (CPU shard sweeps / multi-device CI)."""
    if n < 1:
        raise ValueError("device count must be >= 1")
    _require_uninitialized("host device count")
    _append_xla_flags(f"--xla_force_host_platform_device_count={n}")


def set_x64(enable: bool = True) -> None:
    """Global x64 default.  The engines scope their own ``enable_x64``
    contexts, so benches normally leave this alone; kernels-only legs that
    feed uint64 keys straight into ops use it."""
    import jax
    jax.config.update("jax_enable_x64", enable)


def set_debug_nan(enable: bool = True) -> None:
    import jax
    jax.config.update("jax_debug_nans", enable)


def pin(platform: str | None = None, host_devices: int | None = None,
        x64: bool | None = None, debug_nan: bool | None = None) -> None:
    """Apply every requested pin in the order that keeps them legal
    (env-var flags before any jax.config touch can initialize a backend)."""
    if host_devices is not None:
        force_host_device_count(host_devices)
    if platform is not None:
        set_platform(platform)
    if x64 is not None:
        set_x64(x64)
    if debug_nan is not None:
        set_debug_nan(debug_nan)
