"""End-to-end driver: train a ~100M-param qwen-family model for a few
hundred steps on the synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(A ~100M config: 12 layers x 512 d_model, 8 heads, vocab 32k.)
"""
import argparse
import dataclasses
import sys

from repro.configs import get_config
from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # Register a ~100M config by patching the smoke config family.
    import repro.configs.qwen1_5_0_5b as base
    cfg100m = dataclasses.replace(
        base.config(), name="qwen-100m", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=1408, vocab=32000,
        compute_dtype="float32")
    print(f"training {cfg100m.name}: "
          f"{cfg100m.param_count() / 1e6:.0f}M params")
    orig = train_driver.get_config
    train_driver.get_config = lambda *a, **k: cfg100m
    try:
        train_driver.main([
            "--arch", "qwen1.5-0.5b", "--steps", str(args.steps),
            "--batch", "2", "--seq", "128", "--lr", "1e-3",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--log-every", "20",
        ])
    finally:
        train_driver.get_config = orig


if __name__ == "__main__":
    main()
