"""Batched serving example: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b
"""
import argparse

from repro.launch import serve_lm as serve_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_driver.main([
        "--arch", args.arch, "--smoke", "--batch", str(args.batch),
        "--prompt-len", "48", "--gen", str(args.gen),
    ])


if __name__ == "__main__":
    main()
