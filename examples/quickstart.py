"""Quickstart: build a graph, run both MST engines, check against Kruskal.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import generators, kruskal_ref
from repro.core.mst_api import minimum_spanning_forest
from repro.core.params import GHSParams


def main():
    # An RMAT graph, paper-style: SCALE=10 (1024 vertices), avg degree 32.
    g = generators.generate("rmat", 10, seed=42)
    print(f"graph: {g.num_vertices} vertices, {g.num_edges} edges")

    oracle = kruskal_ref.kruskal(g)
    print(f"kruskal oracle : weight={oracle.total_weight:.4f} "
          f"components={oracle.num_components}")

    forest, stats = minimum_spanning_forest(g, method="boruvka")
    print(f"optimized      : weight={forest.total_weight:.4f} "
          f"rounds={stats.rounds} "
          f"exact_match={np.array_equal(forest.edge_mask, oracle.edge_mask)}")

    forest, stats = minimum_spanning_forest(
        g, method="ghs", params=GHSParams(check_frequency=1))
    print(f"faithful GHS   : weight={forest.total_weight:.4f} "
          f"supersteps={stats.supersteps} msgs={stats.processed} "
          f"exact_match={np.array_equal(forest.edge_mask, oracle.edge_mask)}")


if __name__ == "__main__":
    main()
