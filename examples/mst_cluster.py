"""Distributed MST across shard_map shards — the paper's experiment in
miniature (run with forced host devices to emulate a small cluster):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/mst_cluster.py --shards 8
"""
import argparse
import time

import jax
import numpy as np

from repro.core import generators, kruskal_ref
from repro.core.mst_api import minimum_spanning_forest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=len(jax.devices()))
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--kind", default="rmat")
    ap.add_argument("--method", default="boruvka", choices=["boruvka", "ghs"])
    args = ap.parse_args()

    mesh = None
    if args.shards > 1:
        from repro.compat import make_mesh
        mesh = make_mesh((args.shards,), ("x",))
    g = generators.generate(args.kind, args.scale, seed=7)
    print(f"{args.kind}-{args.scale}: {g.num_vertices} vertices, "
          f"{g.num_edges} edges on {args.shards} shard(s)")
    t0 = time.perf_counter()
    forest, stats = minimum_spanning_forest(g, method=args.method, mesh=mesh)
    dt = time.perf_counter() - t0
    oracle = kruskal_ref.kruskal(g)
    print(f"{args.method}: {dt:.2f}s weight={forest.total_weight:.4f} "
          f"exact={np.array_equal(forest.edge_mask, oracle.edge_mask)} "
          f"stats={stats}")


if __name__ == "__main__":
    main()
